//! The serving engine: glues the quantized weight store, the paged
//! KV-block arena, the budget-aware scheduler and the stats sink around
//! the transformer's chunked incremental decode. Two fronts:
//!
//! * [`Engine`] — synchronous: `enqueue` + `step`/`run_to_completion`, used
//!   by tests, benches and the CLI's self-driven load mode;
//! * [`Engine::spawn`] — a server thread + cloneable [`EngineClient`]s with
//!   a blocking `generate` RPC, used by the closed-loop load generator
//!   (`examples/serve_load.rs`). Worker parallelism *within* a wave splits
//!   the active sequences across scoped threads — safe because every
//!   sequence owns its paged KV chain (`Arc`-shared blocks are read-only
//!   by construction; writable tails are exclusive).
//!
//! One engine iteration ([`Engine::step`]):
//!
//! 1. **retire/admit** — finished sequences publish their prompt chains to
//!    the prefix index and free their blocks; queued sequences admit while
//!    free blocks last (adopting cached prefixes).
//! 2. **plan** — each active sequence is assigned this wave's chunk
//!    (prefill chunk or one decode token) and its blocks are reserved;
//!    when the arena runs dry the engine first evicts LRU prefix entries,
//!    then preempts the newest sequence back to the queue.
//! 3. **wave** — steady-state decode chunks batch into ONE
//!    weight-stationary `Transformer::decode_wave` (every dense weight
//!    matrix read once for the whole batch, attention per-sequence across
//!    scoped threads; [`EngineConfig::wave_batch`]); prefill chunks and
//!    speculative rounds advance per-sequence via
//!    `Transformer::prefill_chunk`, dealt largest-first round-robin across
//!    workers so wave wall time is bounded by the largest single item.
//!    Both paths emit bit-identical tokens by construction.
//!
//! With a draft store configured ([`EngineConfig::spec_draft_store`]) the
//! engine additionally runs **self-speculative decoding**: greedy
//! steady-state decode chunks are opportunistically upgraded to
//! speculative rounds — the sequence's KV chain is forked copy-on-write,
//! up to `spec_k` tokens are drafted through a second, lower-bit weight
//! round-trip of the same model, and all drafts are verified in one
//! all-rows chunk through the target weights. Acceptance is exact greedy
//! token match, so the emitted stream is bit-identical to never having
//! speculated; rejected tails are rolled back and the fork released.

use crate::config::schema::ModelConfig;
use crate::nn::kv::{KvQuant, KvStorage};
use crate::nn::transformer::{Params, Transformer};
use crate::prng::Philox4x32;
use crate::quant::{Geometry, QuantScheme, Scheme};
use crate::serve::batcher::{sample_logits, ActiveSeq, Scheduler, SpecPlan};
use crate::serve::kvcache::{BlockAllocator, PrefixCacheStats};
use crate::serve::protocol::{GenRequest, GenResponse};
use crate::serve::stats::ServeStats;
use crate::serve::weights::WeightStore;
use crate::util::json::num;
use anyhow::{bail, Context, Result};
use std::sync::mpsc;

/// Engine sizing/behaviour knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Max sequences advanced per decode wave.
    pub max_batch: usize,
    /// Positions per KV block (the paging granularity).
    pub kv_block: usize,
    /// Total KV-block arena budget; `0` sizes it for `max_batch` sequences
    /// at full per-sequence capacity (no admission throttling).
    pub kv_blocks: usize,
    /// Max prompt tokens fed per sequence per wave (1 = PR-1 behaviour).
    pub prefill_chunk: usize,
    /// Cross-request prompt-prefix sharing (block-granular, copy-on-write).
    pub prefix_cache: bool,
    /// Worker threads per decode wave (1 = serial).
    pub threads: usize,
    /// Optional end-of-sequence token id.
    pub eos: Option<usize>,
    /// Per-sequence KV capacity in positions (clamped to the model seq_len).
    pub capacity: usize,
    /// How K/V rows are stored in the arena (CLI `--kv-store`): `"f32"`
    /// passthrough (bit-identical to pre-quantization serving) or any
    /// blockwise registry scheme, e.g. `"fp8_e3m4"` / `"int8_sr"` — rows
    /// are then held as packed codes + per-group po2 scales
    /// ([`crate::nn::kv::KvQuant`]).
    pub kv_scheme: Scheme,
    /// Seed for the KV scheme's stochastic-rounding streams (keyed per
    /// layer/position, so re-prefill and prefix reuse stay deterministic).
    pub kv_seed: u64,
    /// Keep an f32 decode mirror next to the packed codes (CLI
    /// `--kv-mirror`). Off by default: quantized blocks are read through
    /// the fused dequant-dot kernels, which are bit-identical to the
    /// mirror — this debug mode exists to *check* that, at the cost of the
    /// full f32 row storage on top of the codes. No effect on `"f32"`
    /// passthrough (which is its own mirror).
    pub kv_mirror: bool,
    /// Record per-request trace timelines (enqueue → admit → prefill /
    /// decode waves → preempt → retire) into the stats' trace buffer —
    /// exported as Chrome trace-event JSONL via `serve --trace-out`.
    pub trace: bool,
    /// Self-speculative decoding draft store (CLI `--spec-draft`): a
    /// registry scheme the serving weights are round-tripped through to
    /// make the cheap draft model (e.g. `"fp4_e2m1_sr"` drafting for an
    /// `"fp8_e3m4"` target). `None` disables speculation. Greedy requests
    /// only; acceptance is exact token match, so outputs are bit-identical
    /// to plain decode — the draft's quality moves throughput, never
    /// correctness.
    pub spec_draft_store: Option<Scheme>,
    /// Draft tokens proposed per speculative round (CLI `--spec-k`).
    /// Ignored unless a draft store is configured.
    pub spec_k: usize,
    /// Batch steady-state decode chunks into one weight-stationary
    /// [`Transformer::decode_wave`] per wave (each dense weight matrix read
    /// once for the whole batch instead of once per sequence). On by
    /// default; the CLI `--no-wave-batch` debug flag turns it off to
    /// A/B-check the bit-identity claim — outputs never differ either way.
    pub wave_batch: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            kv_block: 16,
            kv_blocks: 0,
            prefill_chunk: 8,
            prefix_cache: true,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            eos: None,
            capacity: usize::MAX,
            kv_scheme: crate::quant::resolve("f32").expect("f32 scheme is registered"),
            kv_seed: 0x6B76_5EED,
            kv_mirror: false,
            trace: false,
            spec_draft_store: None,
            spec_k: 4,
            wave_batch: true,
        }
    }
}

impl EngineConfig {
    /// Reject degenerate paging configurations with a descriptive error
    /// (the CLI calls this before building an engine, so `--kv-block 0`
    /// and friends fail cleanly instead of panicking). Model-dependent
    /// checks (KV-scheme row divisibility) live in
    /// [`EngineConfig::validate_for`].
    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            bail!("--max-batch must be positive");
        }
        if self.kv_block == 0 {
            bail!("--kv-block must be positive (positions per KV block)");
        }
        if self.prefill_chunk == 0 {
            bail!("--prefill-chunk must be positive (use 1 for token-at-a-time prefill)");
        }
        if self.capacity == 0 {
            bail!("per-sequence KV capacity must be positive");
        }
        if self.kv_scheme.codec.is_packed() && matches!(self.kv_scheme.geometry, Geometry::None)
        {
            bail!(
                "--kv-store '{}' is an elementwise cast (no block scale); KV quantization \
                 is block-granular — pick a blockwise label such as 'fp8_e3m4' or 'f32'",
                self.kv_scheme.label()
            );
        }
        if self.spec_draft_store.is_some() {
            if self.spec_k == 0 {
                bail!("--spec-k must be positive (draft tokens per speculative round)");
            }
            if self.spec_k > 64 {
                bail!(
                    "--spec-k {} is past any useful acceptance horizon (max 64)",
                    self.spec_k
                );
            }
        }
        Ok(())
    }

    /// [`EngineConfig::validate`] plus the model-dependent KV-scheme
    /// checks. Delegates to [`crate::nn::kv::KvQuant::new`] — the same
    /// constructor `BlockAllocator::with_scheme` runs — so this can never
    /// accept a scheme the arena would then reject (a packed scheme's
    /// block size must divide `d_model`: K/V rows are encoded as whole
    /// scale groups, ragged tails are rejected, not silently padded).
    pub fn validate_for(&self, model: &ModelConfig) -> Result<()> {
        self.validate()?;
        crate::nn::kv::KvQuant::new(self.kv_scheme.clone(), model.d_model, self.kv_seed)
            .map(|_| ())
    }

    /// The arena budget in blocks for a given per-sequence capacity.
    fn resolved_blocks(&self, capacity: usize) -> usize {
        if self.kv_blocks > 0 {
            self.kv_blocks
        } else {
            self.max_batch.max(1) * capacity.div_ceil(self.kv_block.max(1))
        }
    }
}

/// Seed for the draft store's stochastic-rounding streams. Fixed (not the
/// KV seed) so the draft weights are a deterministic function of the
/// target weights and the draft scheme alone.
const SPEC_DRAFT_SEED: u64 = 0xD8AF_75ED;

/// The batched fake-quantized inference engine.
pub struct Engine {
    pub model: Transformer,
    pub params: Params,
    /// Draft weights for speculative decoding: the serving params
    /// round-tripped through [`EngineConfig::spec_draft_store`].
    draft: Option<Params>,
    alloc: BlockAllocator,
    sched: Scheduler,
    pub stats: ServeStats,
    cfg: EngineConfig,
    capacity: usize,
}

impl Engine {
    /// Build from already-materialized params (e.g. a freshly initialized
    /// model, or `WeightStore::to_params`). Degenerate configs panic here;
    /// use [`EngineConfig::validate_for`] first for a clean error.
    pub fn new(model_cfg: ModelConfig, params: Params, cfg: EngineConfig) -> Engine {
        cfg.validate_for(&model_cfg).expect("invalid engine config");
        let model = Transformer::new(model_cfg.clone());
        let capacity = cfg.capacity.min(model_cfg.seq_len);
        let mut quant = KvQuant::new(cfg.kv_scheme.clone(), model_cfg.d_model, cfg.kv_seed)
            .expect("validate_for accepted the kv scheme");
        if cfg.kv_mirror {
            quant = quant.with_mirror();
        }
        let alloc = BlockAllocator::with_quant(
            &model_cfg,
            cfg.resolved_blocks(capacity),
            cfg.kv_block,
            quant,
        );
        let draft = cfg.spec_draft_store.as_ref().map(|scheme| {
            WeightStore::from_params(&params, &model_cfg, scheme.clone(), SPEC_DRAFT_SEED)
                .expect("draft scheme must quantize this model's weights")
                .to_params()
        });
        let sched = Scheduler::new(cfg.max_batch, cfg.prefill_chunk, cfg.prefix_cache);
        let mut stats = ServeStats::new();
        stats.set_kv_store(
            alloc.kv_store_label(),
            alloc.bytes_per_position(),
            alloc.bytes(),
            alloc.encoded_bytes(),
        );
        if cfg.trace {
            stats.enable_trace();
        }
        Engine { model, params, draft, alloc, sched, stats, cfg, capacity }
    }

    /// Build from a quantized snapshot: dequantize-on-load, then serve.
    pub fn from_store(store: &WeightStore, cfg: EngineConfig) -> Engine {
        Engine::new(store.cfg.clone(), store.to_params(), cfg)
    }

    /// Validate and queue a request. The config is re-checked here
    /// (including the KV scheme's geometry against the model): the arena
    /// captured its validated scheme at construction, so a config mutated
    /// afterwards would otherwise be silently ignored — rejecting the
    /// request keeps `cfg` and the arena honest and gives programmatic
    /// misuse a clean error instead.
    pub fn enqueue(&mut self, req: GenRequest) -> Result<()> {
        self.cfg.validate_for(&self.model.cfg)?;
        let vocab = self.model.cfg.vocab;
        if req.prompt.is_empty() {
            bail!("request {}: empty prompt", req.id);
        }
        if let Some(&bad) = req.prompt.iter().find(|&&t| t >= vocab) {
            bail!("request {}: prompt token {bad} out of vocab {vocab}", req.id);
        }
        if req.max_new_tokens == 0 {
            bail!("request {}: max_new_tokens must be > 0", req.id);
        }
        // positions consumed: the whole prompt plus every generated token
        // except the last (which is never fed back)
        let need = req.prompt.len() + req.max_new_tokens - 1;
        if need > self.capacity {
            bail!(
                "request {}: needs {need} KV positions, capacity is {}",
                req.id,
                self.capacity
            );
        }
        // even with every other sequence preempted and the prefix index
        // drained, the request must fit the arena alone
        let blocks = self.alloc.blocks_for(need);
        if blocks > self.alloc.total_blocks() {
            bail!(
                "request {}: needs {blocks} KV blocks of {}, arena has {} (raise --kv-blocks)",
                req.id,
                self.alloc.block_size(),
                self.alloc.total_blocks()
            );
        }
        let (req_id, prompt_len, max_new) = (req.id, req.prompt.len(), req.max_new_tokens);
        self.sched.push(req);
        if let Some(t) = self.stats.trace_mut() {
            t.begin(
                "request",
                req_id,
                vec![("prompt_len", num(prompt_len as f64)), ("max_new", num(max_new as f64))],
            );
        }
        Ok(())
    }

    pub fn queued(&self) -> usize {
        self.sched.pending_len()
    }

    pub fn active(&self) -> usize {
        self.sched.active_len()
    }

    pub fn is_idle(&self) -> bool {
        self.sched.is_idle()
    }

    /// Free arena blocks right now — the admission-control headroom signal
    /// the TCP front end ([`crate::serve::net`]) sheds load on.
    pub fn free_blocks(&self) -> usize {
        self.alloc.free_blocks()
    }

    /// Arena blocks a request needs end-to-end (prompt plus every
    /// generated position except the last). Admission headroom checks
    /// compare this against [`Engine::free_blocks`].
    pub fn blocks_for_request(&self, req: &GenRequest) -> usize {
        let need = req.prompt.len() + req.max_new_tokens.saturating_sub(1);
        self.alloc.blocks_for(need)
    }

    /// KV arena diagnostics: (live blocks, total blocks, high water, bytes).
    pub fn kv_usage(&self) -> (usize, usize, usize, usize) {
        (
            self.alloc.live_blocks(),
            self.alloc.total_blocks(),
            self.alloc.high_water(),
            self.alloc.bytes(),
        )
    }

    /// Prefix-index diagnostics (entries / insertions / evictions).
    pub fn prefix_cache_stats(&self) -> PrefixCacheStats {
        self.alloc.prefix_stats()
    }

    /// Drop every cached prefix chain (releases the blocks the index kept
    /// alive). After a full drain this must leave zero live blocks — the
    /// fuzz harness's leak invariant.
    pub fn clear_prefix_cache(&mut self) {
        self.alloc.prefix_clear();
        self.stats.set_blocks_live(self.alloc.live_blocks());
    }

    /// Canonical label of the KV row-storage scheme (`"f32"`, `"fp8_e3m4"`, …).
    pub fn kv_store(&self) -> &str {
        self.alloc.kv_store_label()
    }

    /// Encoded bytes one cached sequence position costs under the KV scheme.
    pub fn kv_bytes_per_position(&self) -> usize {
        self.alloc.bytes_per_position()
    }

    /// Copy-on-write block copies performed so far.
    pub fn cow_copies(&self) -> usize {
        self.alloc.cow_copies
    }

    /// One engine iteration: admit from the queue, plan and reserve each
    /// active sequence's chunk (evicting cached prefixes / preempting the
    /// newest sequence if the arena runs dry), advance every sequence by
    /// its chunk — steady-state decodes batched into one weight-stationary
    /// `decode_wave`, the rest parallel across workers — and retire
    /// finished sequences. Returns completions.
    pub fn step(&mut self) -> Vec<GenResponse> {
        // deadline sweep first: an expired queued request must not be
        // admitted, and an expired active sequence must not burn a wave
        let mut expired = self.sched.expire_deadlines(&mut self.alloc, &mut self.stats);
        if !expired.is_empty() {
            self.stats.set_blocks_live(self.alloc.live_blocks());
        }
        self.sched.admit(&self.model.cfg, self.capacity, &mut self.alloc, &mut self.stats);
        if self.sched.active.is_empty() {
            return expired;
        }
        // ---- plan: pick + reserve this wave's chunk per sequence ----
        // Active order is admission order, so preempting the newest only
        // ever removes the tail — already-planned chunks stay valid.
        let prefill_chunk = self.sched.prefill_chunk;
        let mut chunks: Vec<usize> = Vec::with_capacity(self.sched.active.len());
        let mut w = 0;
        'plan: while w < self.sched.active.len() {
            let mut chunk = self.sched.active[w].next_chunk_len(prefill_chunk);
            loop {
                let fit = self.alloc.max_appendable(&self.sched.active[w].kv);
                if fit > 0 {
                    chunk = chunk.min(fit);
                    if self.alloc.reserve(&mut self.sched.active[w].kv, chunk) {
                        chunks.push(chunk);
                        w += 1;
                        continue 'plan;
                    }
                }
                // arena dry: reclaim cached prefixes first, then preempt
                if self.alloc.prefix_evict_lru() {
                    continue;
                }
                match self.sched.preempt_newest(&mut self.alloc, &mut self.stats) {
                    Some(idx) if idx == w => continue 'plan, // victim was us
                    Some(idx) => {
                        debug_assert!(idx > w, "victim must be unplanned");
                        continue;
                    }
                    None => unreachable!(
                        "arena cannot advance any sequence (enqueue bounds each request)"
                    ),
                }
            }
        }
        let n = self.sched.active.len();
        if n == 0 {
            return Vec::new(); // everything preempted (arena momentarily dry)
        }
        // ---- spec plan: opportunistically upgrade greedy steady-state
        // decode chunks (chunk == 1, cache caught up) into speculative
        // rounds. Ordering matters: fork FIRST (the fork shares the
        // committed chain), then reserve the target — reserve's
        // make_tail_exclusive copy-on-writes the now-shared tail so the
        // verify chunk and the draft decode write disjoint blocks. If the
        // arena can't host the round, undo everything and fall back to the
        // already-planned plain decode token.
        if self.draft.is_some() {
            for (w, seq) in self.sched.active.iter_mut().enumerate() {
                if chunks[w] != 1 || seq.in_prefill() || seq.req.temperature > 0.0 {
                    continue;
                }
                let base = seq.kv.len();
                // cap so a full sweep (k accepted + 1 bonus) never
                // overshoots max_new_tokens or the position capacity
                let remaining = seq.req.max_new_tokens - seq.generated.len();
                let k = self
                    .cfg
                    .spec_k
                    .min(remaining.saturating_sub(1))
                    .min(seq.kv.capacity().saturating_sub(base + 1));
                if k == 0 {
                    continue;
                }
                let mut fork = self
                    .alloc
                    .fork_seq(&self.model.cfg, &seq.kv)
                    .expect("forked chain blocks are live");
                if !self.alloc.reserve(&mut seq.kv, k + 1) || !self.alloc.reserve(&mut fork, k) {
                    // arena dry mid-round: release the fork (the tail is
                    // exclusive again), drop any stray blocks the failed
                    // reserve attached, and re-establish the plain
                    // one-token reservation (its block was just returned)
                    self.alloc.release_fork(fork).expect("fork chain was live");
                    self.alloc.rollback_to(&mut seq.kv, base).expect("spec tail was live");
                    assert!(
                        self.alloc.reserve(&mut seq.kv, 1),
                        "plain decode reservation must re-establish after spec fallback"
                    );
                    continue;
                }
                seq.spec = Some(SpecPlan {
                    draft_kv: fork,
                    k,
                    base_len: base,
                    drafted: 0,
                    accepted: 0,
                    commit_len: base,
                });
            }
        }
        // stamp the wave BEFORE the compute so wall-clock throughput
        // includes the first wave's work
        self.stats.record_wave(n);
        for &c in &chunks {
            if c > 1 {
                self.stats.record_prefill_chunk(c);
            }
        }
        self.stats.record_blocks(self.alloc.live_blocks(), self.alloc.total_blocks());
        // per-sequence wave spans: label + chunk captured at plan time (a
        // chunk is a decode step iff it feeds exactly the one sampled token)
        let wave_start = self.stats.trace().map(|t| t.now_us());
        let wave_meta: Vec<(u64, usize, bool)> = if wave_start.is_some() {
            self.sched
                .active
                .iter()
                .zip(&chunks)
                .map(|(seq, &c)| (seq.req.id, c, c == 1 && !seq.in_prefill()))
                .collect()
        } else {
            Vec::new()
        };
        // ---- wave: advance every sequence by its chunk ----
        let wave_batch_n;
        {
            let model = &self.model;
            let params = &self.params;
            let draft = self.draft.as_ref();
            let eos = self.cfg.eos;
            let wave_batch = self.cfg.wave_batch;
            let threads = self.cfg.threads.max(1);
            let work: Vec<(&mut ActiveSeq, usize)> =
                self.sched.active.iter_mut().zip(chunks).collect();
            // split the wave: steady-state decode chunks with no
            // speculative round in flight batch into ONE weight-stationary
            // `decode_wave` — every dense weight matrix is read once for
            // the whole batch instead of once per sequence. Prefill chunks
            // and speculative rounds stay on the per-sequence path.
            let (mut batch, rest): (Vec<_>, Vec<_>) = if wave_batch {
                work.into_iter().partition(|(seq, chunk)| {
                    *chunk == 1 && !seq.in_prefill() && seq.spec.is_none()
                })
            } else {
                (Vec::new(), work)
            };
            wave_batch_n = batch.len();
            // deal per-sequence items largest-estimate-first round-robin
            // across workers: a contiguous split hands all the long prefill
            // chunks to one thread when requests arrive sorted, bounding
            // the wave by a chunk-sum; interleaving bounds it by the
            // largest single item. Cost model: dense work scales with
            // positions fed, attention with the end position (a spec round
            // feeds k draft steps plus a k+1 verify chunk).
            let mut costed: Vec<(usize, (&mut ActiveSeq, usize))> = rest
                .into_iter()
                .map(|it| {
                    let fed = match &it.0.spec {
                        Some(plan) => 2 * plan.k + 1,
                        None => it.1,
                    };
                    (fed * (1 + it.0.kv.len() + fed), it)
                })
                .collect();
            costed.sort_by_key(|&(cost, _)| std::cmp::Reverse(cost));
            let nt = threads.clamp(1, costed.len().max(1));
            let mut bins: Vec<Vec<(&mut ActiveSeq, usize)>> =
                (0..nt).map(|_| Vec::new()).collect();
            for (i, (_, it)) in costed.into_iter().enumerate() {
                bins[i % nt].push(it);
            }
            // the batched decode runs on this thread (inside the scope, so
            // it overlaps the spawned per-sequence work); attention within
            // it shards across its own scoped threads
            let run_batch = |batch: &mut Vec<(&mut ActiveSeq, usize)>| {
                if batch.is_empty() {
                    return;
                }
                let tokens: Vec<usize> =
                    batch.iter().map(|(seq, _)| seq.next_tokens(1)[0]).collect();
                let mut caches: Vec<_> =
                    batch.iter_mut().map(|(seq, _)| &mut seq.kv).collect();
                let logits = model.decode_wave(params, &tokens, &mut caches, threads);
                drop(caches);
                for (s, (seq, _)) in batch.iter_mut().enumerate() {
                    seq.absorb(logits.row(s), eos);
                }
            };
            if threads == 1 {
                for bin in bins.iter_mut() {
                    for (seq, chunk) in bin.iter_mut() {
                        advance(model, params, draft, seq, *chunk, eos);
                    }
                }
                run_batch(&mut batch);
            } else {
                std::thread::scope(|sc| {
                    for mut bin in bins.into_iter().filter(|b| !b.is_empty()) {
                        sc.spawn(move || {
                            for (seq, chunk) in bin.iter_mut() {
                                advance(model, params, draft, seq, *chunk, eos);
                            }
                        });
                    }
                    run_batch(&mut batch);
                });
            }
        }
        if self.cfg.wave_batch {
            self.stats.record_wave_batch(wave_batch_n);
        }
        // ---- resolve speculative rounds (before retirement, so a
        // finishing sequence publishes a clean chain): roll the target
        // cache back over the rejected tail, release the draft fork,
        // account the round ----
        let mut spec_events: Vec<(u64, usize, usize)> = Vec::new();
        for seq in self.sched.active.iter_mut() {
            if let Some(plan) = seq.spec.take() {
                self.alloc
                    .rollback_to(&mut seq.kv, plan.commit_len)
                    .expect("rejected speculative tail was live");
                self.alloc.release_fork(plan.draft_kv).expect("draft fork chain was live");
                self.stats.record_spec(plan.drafted, plan.accepted);
                spec_events.push((seq.req.id, plan.drafted, plan.accepted));
            }
        }
        if let Some(start) = wave_start {
            if let Some(t) = self.stats.trace_mut() {
                let dur = t.now_us().saturating_sub(start).max(1);
                for &(tid, positions, is_decode) in &wave_meta {
                    t.complete(
                        if is_decode { "decode" } else { "prefill" },
                        tid,
                        start,
                        dur,
                        vec![("positions", num(positions as f64))],
                    );
                }
                for &(tid, drafted, accepted) in &spec_events {
                    t.complete(
                        "spec",
                        tid,
                        start,
                        dur,
                        vec![
                            ("drafted", num(drafted as f64)),
                            ("accepted", num(accepted as f64)),
                        ],
                    );
                }
            }
        }
        let done = self.sched.retire(&mut self.alloc);
        for r in &done {
            self.stats.record_completion(r);
        }
        // retirement is a release edge too: keep the occupancy-over-time
        // gauge honest between waves (the fuzz harness asserts it returns
        // to zero after a drain + prefix clear)
        self.stats.set_blocks_live(self.alloc.live_blocks());
        expired.extend(done);
        expired
    }

    /// Drive the engine until queue and batch drain; returns all
    /// completions in finish order.
    pub fn run_to_completion(&mut self) -> Vec<GenResponse> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step());
        }
        out
    }

    /// Start a server thread; returns a handle whose clients issue blocking
    /// `generate` calls. Dropping the handle and every client stops the
    /// server once in-flight work drains.
    pub fn spawn(self) -> EngineHandle {
        let (tx, rx) = mpsc::channel::<(GenRequest, mpsc::Sender<GenResponse>)>();
        let join = std::thread::spawn(move || serve_loop(self, rx));
        EngineHandle { tx: Some(tx), join }
    }
}

/// Advance one sequence by its planned chunk (its blocks are reserved).
/// A sequence carrying a [`SpecPlan`] runs a speculative round instead of
/// the plain chunk; the plan is re-attached for the planner thread to
/// resolve (rollback + fork release) after the wave.
fn advance(
    model: &Transformer,
    params: &Params,
    draft: Option<&Params>,
    seq: &mut ActiveSeq,
    chunk: usize,
    eos: Option<usize>,
) {
    if let Some(mut plan) = seq.spec.take() {
        let draft_params = draft.expect("a spec plan implies a draft store");
        speculate(model, params, draft_params, seq, &mut plan, eos);
        seq.spec = Some(plan);
        return;
    }
    let tokens = seq.next_tokens(chunk);
    let logits = model.prefill_chunk(params, &tokens, &mut seq.kv);
    seq.absorb(&logits, eos);
}

/// One speculative round (greedy steady-state decode; target and fork
/// blocks are reserved). Draft `plan.k` tokens token-at-a-time through
/// the low-bit draft params on the CoW fork, then verify them all in ONE
/// all-rows chunk through the target params: row `i` of the verify logits
/// is bit-identical to what a sequential greedy decode would have seen at
/// position `base_len + i`, so exact token match is a sound acceptance
/// rule — the emitted stream is bit-identical to never speculating. Every
/// round emits at least one token (the correction row on the first miss,
/// or the bonus row after a full sweep), so the round is never slower
/// than a plain decode step in tokens emitted.
fn speculate(
    model: &Transformer,
    params: &Params,
    draft: &Params,
    seq: &mut ActiveSeq,
    plan: &mut SpecPlan,
    eos: Option<usize>,
) {
    let t_last = seq.next_tokens(1)[0];
    // draft pass: greedy argmax through the draft weights on the fork
    // (temperature 0 never touches the throwaway rng)
    let mut throwaway = Philox4x32::new(0);
    let mut drafts = Vec::with_capacity(plan.k);
    let mut tok = t_last;
    for _ in 0..plan.k {
        let logits = model.prefill_chunk(draft, &[tok], &mut plan.draft_kv);
        tok = sample_logits(&logits, 0.0, 0, &mut throwaway);
        drafts.push(tok);
    }
    plan.drafted = drafts.len();
    // verify wave: [t_last, draft_0, …, draft_{k-1}] through the target
    let mut chunk = Vec::with_capacity(plan.k + 1);
    chunk.push(t_last);
    chunk.extend_from_slice(&drafts);
    let all = model.prefill_chunk_logits(params, &chunk, &mut seq.kv);
    let mut emitted = 0;
    for i in 0..=plan.k {
        seq.absorb(all.row(i), eos);
        emitted += 1;
        if i < plan.k {
            let matched = drafts[i] == *seq.generated.last().expect("absorb emitted a token");
            if matched {
                plan.accepted += 1;
            }
            if !matched || seq.finish.is_some() {
                break;
            }
        }
    }
    // the planner rolls the cache back here: exactly the state a
    // sequential decode of the emitted tokens would have left
    plan.commit_len = plan.base_len + emitted;
}

fn serve_loop(
    mut engine: Engine,
    rx: mpsc::Receiver<(GenRequest, mpsc::Sender<GenResponse>)>,
) -> ServeStats {
    let mut responders: Vec<(u64, mpsc::Sender<GenResponse>)> = Vec::new();
    let mut disconnected = false;
    loop {
        // block for work when idle; otherwise just drain whatever arrived
        if engine.is_idle() && !disconnected {
            match rx.recv() {
                Ok((req, resp_tx)) => accept(&mut engine, &mut responders, req, resp_tx),
                Err(_) => disconnected = true,
            }
        }
        loop {
            match rx.try_recv() {
                Ok((req, resp_tx)) => accept(&mut engine, &mut responders, req, resp_tx),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        for resp in engine.step() {
            if let Some(i) = responders.iter().position(|(id, _)| *id == resp.id) {
                let (_, tx) = responders.swap_remove(i);
                let _ = tx.send(resp);
            }
        }
        if disconnected && engine.is_idle() {
            return engine.stats;
        }
    }
}

fn accept(
    engine: &mut Engine,
    responders: &mut Vec<(u64, mpsc::Sender<GenResponse>)>,
    req: GenRequest,
    resp_tx: mpsc::Sender<GenResponse>,
) {
    let id = req.id;
    // responses route back by request id, so a second in-flight request
    // with the same id would be misdelivered — reject it up front
    if responders.iter().any(|(rid, _)| *rid == id) {
        return; // dropping resp_tx errors the client's recv
    }
    match engine.enqueue(req) {
        Ok(()) => responders.push((id, resp_tx)),
        Err(_) => drop(resp_tx), // client's recv errors: request rejected
    }
}

/// Handle to a spawned engine thread.
pub struct EngineHandle {
    tx: Option<mpsc::Sender<(GenRequest, mpsc::Sender<GenResponse>)>>,
    join: std::thread::JoinHandle<ServeStats>,
}

impl EngineHandle {
    /// A cloneable client for issuing blocking generate calls.
    pub fn client(&self) -> EngineClient {
        EngineClient { tx: self.tx.as_ref().expect("handle already shut down").clone() }
    }

    /// Stop accepting requests, wait for in-flight work, return the stats.
    /// All [`EngineClient`]s must be dropped for the server to exit.
    pub fn shutdown(mut self) -> ServeStats {
        self.tx.take(); // close our sender
        self.join.join().expect("engine thread panicked")
    }
}

/// Cloneable blocking client to a spawned engine.
#[derive(Clone)]
pub struct EngineClient {
    tx: mpsc::Sender<(GenRequest, mpsc::Sender<GenResponse>)>,
}

impl EngineClient {
    /// Submit a request and block until its response (closed-loop client).
    /// Request ids must be unique among in-flight requests; a concurrent
    /// duplicate id is rejected (this call returns an error).
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send((req, rtx))
            .ok()
            .context("engine is shut down")?;
        rrx.recv().ok().context("request rejected or engine stopped")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::Arch;
    use crate::nn::transformer::DecodeCache;

    fn tiny_engine(max_batch: usize, kv_blocks: usize, threads: usize) -> Engine {
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(3);
        Engine::new(
            cfg,
            params,
            EngineConfig {
                max_batch,
                kv_block: 8,
                kv_blocks,
                prefill_chunk: 4,
                prefix_cache: false,
                threads,
                ..EngineConfig::default()
            },
        )
    }

    #[test]
    fn single_request_greedy_matches_direct_decode() {
        let mut e = tiny_engine(4, 0, 1);
        let prompt = vec![5usize, 9, 23];
        e.enqueue(GenRequest::greedy(1, prompt.clone(), 6)).unwrap();
        let out = e.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 6);

        // reference: direct greedy loop over decode_step
        let mut cache = DecodeCache::new(&e.model.cfg, 64);
        let mut fed: Vec<usize> = prompt.clone();
        let mut generated = Vec::new();
        for i in 0.. {
            let logits = e.model.decode_step(&e.params, fed[i], &mut cache);
            if i + 1 < fed.len() {
                continue;
            }
            let mut best = 0;
            for (c, &v) in logits.iter().enumerate() {
                if v > logits[best] {
                    best = c;
                }
            }
            generated.push(best);
            if generated.len() == 6 {
                break;
            }
            fed.push(best);
        }
        assert_eq!(out[0].tokens, generated);
    }

    #[test]
    fn concurrent_requests_batch_and_all_complete() {
        let mut e = tiny_engine(4, 0, 2);
        for id in 0..6 {
            e.enqueue(GenRequest::greedy(id, vec![(id as usize) % 50 + 1, 2, 3], 4 + id as usize % 3))
                .unwrap();
        }
        let out = e.run_to_completion();
        assert_eq!(out.len(), 6);
        for r in &out {
            assert!(!r.tokens.is_empty());
            assert!(r.total_s >= 0.0 && r.ttft_s >= 0.0);
        }
        assert!(e.stats.max_occupancy() > 1, "continuous batching never batched");
        assert_eq!(e.stats.completed(), 6);
        let (live, total, high_water, bytes) = e.kv_usage();
        assert_eq!(live, 0, "blocks leaked");
        assert_eq!(total, 4 * 64usize.div_ceil(8));
        assert!(high_water >= 2);
        assert!(bytes > 0);
    }

    #[test]
    fn batching_is_transparent_to_results() {
        // the same greedy requests must produce identical tokens whether
        // served one-at-a-time or continuously batched on worker threads
        let reqs: Vec<GenRequest> =
            (0..5).map(|id| GenRequest::greedy(id, vec![1 + id as usize * 7, 4], 5)).collect();
        let mut serial = tiny_engine(1, 0, 1);
        let mut batched = tiny_engine(4, 0, 2);
        for r in &reqs {
            serial.enqueue(r.clone()).unwrap();
            batched.enqueue(r.clone()).unwrap();
        }
        let mut a = serial.run_to_completion();
        let mut b = batched.run_to_completion();
        a.sort_by_key(|r| r.id);
        b.sort_by_key(|r| r.id);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens, "req {}", x.id);
        }
        assert_eq!(serial.stats.max_occupancy(), 1);
        assert!(batched.stats.max_occupancy() > 1);
    }

    #[test]
    fn invalid_requests_rejected() {
        let mut e = tiny_engine(2, 4, 1);
        assert!(e.enqueue(GenRequest::greedy(1, vec![], 4)).is_err());
        assert!(e.enqueue(GenRequest::greedy(2, vec![9999], 4)).is_err());
        assert!(e.enqueue(GenRequest::greedy(3, vec![1], 0)).is_err());
        let too_long = vec![1usize; 200]; // tiny seq_len is 64
        assert!(e.enqueue(GenRequest::greedy(4, too_long, 4)).is_err());
        // fits the position capacity but not the 4-block arena
        let too_wide = vec![1usize; 40];
        let err = e.enqueue(GenRequest::greedy(5, too_wide, 4)).unwrap_err();
        assert!(err.to_string().contains("kv-blocks"), "{err}");
        assert!(e.is_idle());
    }

    #[test]
    fn degenerate_configs_fail_validation_cleanly() {
        let ok = EngineConfig::default();
        assert!(ok.validate().is_ok());
        let zero_block = EngineConfig { kv_block: 0, ..EngineConfig::default() };
        assert!(zero_block.validate().unwrap_err().to_string().contains("kv-block"));
        let zero_chunk = EngineConfig { prefill_chunk: 0, ..EngineConfig::default() };
        assert!(zero_chunk.validate().unwrap_err().to_string().contains("prefill-chunk"));
        let zero_batch = EngineConfig { max_batch: 0, ..EngineConfig::default() };
        assert!(zero_batch.validate().is_err());
    }

    #[test]
    fn kv_scheme_validation_rejects_unhostable_geometries() {
        let cfg = ModelConfig::tiny(Arch::Gpt2); // d_model 64
        // packed codec with elementwise geometry: no block scale to share
        let elementwise = EngineConfig {
            kv_scheme: crate::quant::resolve("fp8_e3m4").unwrap().elementwise(),
            ..EngineConfig::default()
        };
        let err = elementwise.validate().unwrap_err().to_string();
        assert!(err.contains("elementwise"), "{err}");
        assert!(err.contains("fp8_e3m4"), "{err}");
        // block 48 does not divide d_model 64: rejected by the model check
        let ragged = EngineConfig {
            kv_scheme: crate::quant::resolve("fp8_e3m4").unwrap().with_block(48),
            ..EngineConfig::default()
        };
        assert!(ragged.validate().is_ok(), "divisibility needs the model config");
        let err = ragged.validate_for(&cfg).unwrap_err().to_string();
        assert!(err.contains("does not divide"), "{err}");
        assert!(err.contains("48"), "{err}");
        // the good cases pass both levels
        for label in ["f32", "fp8_e3m4", "int8_sr", "bf16"] {
            let good = EngineConfig {
                kv_scheme: crate::quant::resolve(label).unwrap(),
                ..EngineConfig::default()
            };
            assert!(good.validate_for(&cfg).is_ok(), "{label}");
        }
    }

    #[test]
    fn enqueue_rejects_invalid_kv_scheme_with_clean_error() {
        // an engine whose config is corrupted after construction must fail
        // at enqueue with the validation error, not panic at first commit
        let mut e = tiny_engine(2, 0, 1);
        e.cfg.kv_scheme = crate::quant::resolve("fp8_e3m4").unwrap().with_block(48);
        let err = e.enqueue(GenRequest::greedy(1, vec![2, 3], 4)).unwrap_err().to_string();
        assert!(err.contains("does not divide"), "{err}");
        e.cfg.kv_scheme = crate::quant::resolve("int8_sr").unwrap().elementwise();
        let err = e.enqueue(GenRequest::greedy(2, vec![2, 3], 4)).unwrap_err().to_string();
        assert!(err.contains("elementwise"), "{err}");
        assert!(e.is_idle());
    }

    #[test]
    fn quantized_kv_engine_completes_and_reports_store() {
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(5);
        let mut e = Engine::new(
            cfg,
            params,
            EngineConfig {
                max_batch: 4,
                kv_block: 8,
                kv_blocks: 0,
                prefill_chunk: 4,
                prefix_cache: true,
                threads: 2,
                kv_scheme: crate::quant::resolve("fp8_e3m4").unwrap(),
                ..EngineConfig::default()
            },
        );
        assert_eq!(e.kv_store(), "fp8_e3m4");
        assert!(e.kv_bytes_per_position() < 2 * e.model.cfg.n_layer * e.model.cfg.d_model * 4);
        for id in 0..5u64 {
            e.enqueue(GenRequest::greedy(id, vec![1 + id as usize * 3, 7, 9], 4)).unwrap();
        }
        let out = e.run_to_completion();
        assert_eq!(out.len(), 5);
        for r in &out {
            assert_eq!(r.tokens.len(), 4);
        }
        e.clear_prefix_cache();
        let (live, ..) = e.kv_usage();
        assert_eq!(live, 0, "quantized blocks leaked");
        let j = e.stats.bench_json("kv", vec![]);
        assert_eq!(j.get("kv_store").as_str(), Some("fp8_e3m4"));
        assert!(j.get("kv_bytes_per_position").as_usize().unwrap() > 0);
    }

    #[test]
    fn quantized_kv_greedy_outputs_are_deterministic() {
        // same config + same requests => identical tokens, including for
        // stochastic-rounding KV (draws are keyed per layer/position)
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(6);
        let run = || {
            let mut e = Engine::new(
                cfg.clone(),
                params.clone(),
                EngineConfig {
                    max_batch: 2,
                    kv_block: 4,
                    kv_blocks: 8, // tight: forces preemption interleave
                    prefill_chunk: 3,
                    prefix_cache: true,
                    threads: 1,
                    kv_scheme: crate::quant::resolve("int8_sr").unwrap(),
                    ..EngineConfig::default()
                },
            );
            for id in 0..4u64 {
                let prompt: Vec<usize> = (0..9).map(|k| (id as usize * 11 + k * 2) % 50).collect();
                e.enqueue(GenRequest::greedy(id, prompt, 5)).unwrap();
            }
            let mut out = e.run_to_completion();
            out.sort_by_key(|r| r.id);
            out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "int8_sr KV serving must be reproducible");
    }

    #[test]
    fn mirror_mode_outputs_match_fused_exactly() {
        // the f32 decode mirror is a debug view of the same packed codes:
        // flipping it on must not change a single sampled token, even for
        // a 4-bit stochastic-rounding store
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(9);
        let run = |mirror: bool| {
            let mut e = Engine::new(
                cfg.clone(),
                params.clone(),
                EngineConfig {
                    max_batch: 2,
                    kv_block: 8,
                    prefill_chunk: 4,
                    threads: 1,
                    kv_scheme: crate::quant::resolve("fp4_e2m1_sr").unwrap(),
                    kv_mirror: mirror,
                    ..EngineConfig::default()
                },
            );
            // codes + scales only; the mirror never inflates this number
            assert_eq!(e.kv_bytes_per_position(), 160, "fp4 tiny-config bytes per position");
            for id in 0..3u64 {
                let prompt: Vec<usize> = (0..7).map(|k| (id as usize * 13 + k * 3) % 50).collect();
                e.enqueue(GenRequest::greedy(id, prompt, 5)).unwrap();
            }
            let mut out = e.run_to_completion();
            out.sort_by_key(|r| r.id);
            out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(false), run(true), "fused reads must be bit-identical to the mirror");
    }

    #[test]
    fn wave_batching_is_bit_identical_to_per_sequence_decode() {
        // flipping the weight-stationary batched decode off must not change
        // a single token — across worker counts, a quantized KV store, a
        // tight arena (preemption churn) and speculative decoding, which
        // routes around the batch but shares the wave
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(13);
        let run = |wave_batch: bool, threads: usize, kv_blocks: usize, spec: bool| {
            let mut e = Engine::new(
                cfg.clone(),
                params.clone(),
                EngineConfig {
                    max_batch: 4,
                    kv_block: 8,
                    kv_blocks,
                    prefill_chunk: 4,
                    prefix_cache: false,
                    threads,
                    kv_scheme: crate::quant::resolve("fp8_e3m4").unwrap(),
                    spec_draft_store: spec
                        .then(|| crate::quant::resolve("fp4_e2m1_sr").unwrap()),
                    spec_k: 3,
                    wave_batch,
                    ..EngineConfig::default()
                },
            );
            for id in 0..6u64 {
                let prompt: Vec<usize> =
                    (0..5 + id as usize).map(|k| (id as usize * 7 + k * 3) % 50).collect();
                e.enqueue(GenRequest::greedy(id, prompt, 6)).unwrap();
            }
            let mut out = e.run_to_completion();
            out.sort_by_key(|r| r.id);
            let tokens: Vec<_> = out.into_iter().map(|r| r.tokens).collect();
            let (live, ..) = e.kv_usage();
            assert_eq!(live, 0, "wave_batch={wave_batch}: blocks leaked");
            (tokens, e)
        };
        for (threads, kv_blocks, spec) in [(1, 0, false), (3, 0, false), (2, 6, false), (2, 0, true)]
        {
            let (on, e_on) = run(true, threads, kv_blocks, spec);
            let (off, _) = run(false, threads, kv_blocks, spec);
            assert_eq!(
                on, off,
                "threads={threads} kv_blocks={kv_blocks} spec={spec}: \
                 wave batching changed outputs"
            );
            assert!(
                e_on.stats.wave_batch_waves() > 0,
                "threads={threads}: no wave was ever batched"
            );
        }
    }

    #[test]
    fn spec_config_validation() {
        let spec = |k: usize| EngineConfig {
            spec_draft_store: Some(crate::quant::resolve("fp4_e2m1_sr").unwrap()),
            spec_k: k,
            ..EngineConfig::default()
        };
        let err = spec(0).validate().unwrap_err().to_string();
        assert!(err.contains("spec-k"), "{err}");
        let err = spec(65).validate().unwrap_err().to_string();
        assert!(err.contains("spec-k"), "{err}");
        assert!(spec(1).validate().is_ok());
        assert!(spec(64).validate().is_ok());
        // spec_k is ignored (not validated) when speculation is off
        let off = EngineConfig { spec_k: 0, ..EngineConfig::default() };
        assert!(off.validate().is_ok());
    }

    #[test]
    fn speculative_greedy_is_bit_identical_to_plain_decode() {
        // the load-bearing invariant: speculation must never change a
        // single greedy token, whatever the draft store or depth
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(11);
        let run = |spec: Option<&str>, spec_k: usize| {
            let mut e = Engine::new(
                cfg.clone(),
                params.clone(),
                EngineConfig {
                    max_batch: 4,
                    kv_block: 8,
                    prefill_chunk: 4,
                    threads: 2,
                    spec_draft_store: spec.map(|l| crate::quant::resolve(l).unwrap()),
                    spec_k,
                    ..EngineConfig::default()
                },
            );
            for id in 0..4u64 {
                let prompt: Vec<usize> =
                    (0..7).map(|k| (id as usize * 9 + k * 4) % 50).collect();
                e.enqueue(GenRequest::greedy(id, prompt, 8)).unwrap();
            }
            let mut out = e.run_to_completion();
            out.sort_by_key(|r| r.id);
            (out.into_iter().map(|r| r.tokens).collect::<Vec<_>>(), e)
        };
        let (plain, baseline) = run(None, 4);
        assert_eq!(baseline.stats.spec_rounds(), 0);
        for (label, k) in [("fp4_e2m1_sr", 4), ("fp8_e3m4", 3), ("int8_sr", 1)] {
            let (spec, e) = run(Some(label), k);
            assert_eq!(plain, spec, "{label}/k={k}: spec decode changed greedy outputs");
            assert!(e.stats.spec_rounds() > 0, "{label}: no speculative rounds ran");
            assert!(e.stats.spec_drafted() > 0, "{label}: rounds drafted nothing");
            let (live, ..) = e.kv_usage();
            assert_eq!(live, 0, "{label}: speculation leaked blocks");
        }
    }

    #[test]
    fn identical_draft_store_accepts_every_token() {
        // accept-all: an f32 (lossless) draft round-trip makes the draft
        // weights bit-identical to the target, and the fork writes each
        // draft position through the same position-keyed KV encoding the
        // verify pass uses — so every draft matches and every round
        // sweeps k accepted + 1 bonus
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(7);
        let mut e = Engine::new(
            cfg,
            params,
            EngineConfig {
                max_batch: 2,
                kv_block: 8,
                prefill_chunk: 4,
                threads: 1,
                spec_draft_store: Some(crate::quant::resolve("f32").unwrap()),
                spec_k: 3,
                ..EngineConfig::default()
            },
        );
        e.enqueue(GenRequest::greedy(1, vec![3, 1, 4, 1, 5], 9)).unwrap();
        let out = e.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 9);
        assert!(e.stats.spec_rounds() >= 2, "9 tokens at k=3 needs multiple rounds");
        assert_eq!(
            e.stats.spec_accepted(),
            e.stats.spec_drafted(),
            "a lossless draft must never be rejected"
        );
        assert_eq!(e.stats.spec_acceptance_rate(), 1.0);
        let (live, ..) = e.kv_usage();
        assert_eq!(live, 0);
    }

    #[test]
    fn unrelated_draft_store_rolls_back_everything_and_stays_exact() {
        // rollback-all: swap the draft weights for a completely unrelated
        // model AFTER construction — drafts are effectively random tokens,
        // nearly every round rejects at the first row and rolls the whole
        // speculative tail back. Outputs must still be bit-identical.
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(11);
        let mk = |sabotage: bool| {
            let mut e = Engine::new(
                cfg.clone(),
                params.clone(),
                EngineConfig {
                    max_batch: 2,
                    kv_block: 8,
                    prefill_chunk: 4,
                    threads: 1,
                    spec_draft_store: sabotage
                        .then(|| crate::quant::resolve("fp8_e3m4").unwrap()),
                    spec_k: 4,
                    ..EngineConfig::default()
                },
            );
            if sabotage {
                e.draft = Some(e.model.init_params(999));
            }
            for id in 0..2u64 {
                e.enqueue(GenRequest::greedy(id, vec![2 + id as usize, 7, 9], 7)).unwrap();
            }
            let mut out = e.run_to_completion();
            out.sort_by_key(|r| r.id);
            (out.into_iter().map(|r| r.tokens).collect::<Vec<_>>(), e)
        };
        let (plain, _) = mk(false);
        let (spec, e) = mk(true);
        assert_eq!(plain, spec, "rejected drafts must leave outputs untouched");
        assert!(e.stats.spec_rounds() > 0);
        assert!(
            e.stats.spec_accepted() < e.stats.spec_drafted(),
            "an unrelated draft model cannot be always-right ({} of {})",
            e.stats.spec_accepted(),
            e.stats.spec_drafted()
        );
        let (live, ..) = e.kv_usage();
        assert_eq!(live, 0, "rolled-back rounds leaked blocks");
    }

    #[test]
    fn spec_under_tight_arena_preempts_without_leaks() {
        // fork-under-pressure: a 4-block arena cannot host most rounds
        // (fork + k+1 reservation), so the planner exercises the fallback
        // path constantly while preemption churns sequences in and out.
        // Everything must still complete bit-identically and leak-free.
        let mk_reqs = || -> Vec<GenRequest> {
            (0..6)
                .map(|id| {
                    let prompt: Vec<usize> =
                        (0..12).map(|k| (id as usize * 5 + k * 3) % 50).collect();
                    GenRequest::greedy(id, prompt, 6)
                })
                .collect()
        };
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(3);
        let mk = |kv_blocks: usize, spec: bool| {
            Engine::new(
                cfg.clone(),
                params.clone(),
                EngineConfig {
                    max_batch: 4,
                    kv_block: 8,
                    kv_blocks,
                    prefill_chunk: 4,
                    prefix_cache: false,
                    threads: 1,
                    spec_draft_store: spec
                        .then(|| crate::quant::resolve("fp4_e2m1_sr").unwrap()),
                    spec_k: 4,
                    ..EngineConfig::default()
                },
            )
        };
        let mut tight = mk(4, true);
        let mut roomy = mk(0, false);
        for r in mk_reqs() {
            tight.enqueue(r.clone()).unwrap();
            roomy.enqueue(r).unwrap();
        }
        let mut a = tight.run_to_completion();
        let mut b = roomy.run_to_completion();
        assert_eq!(a.len(), 6);
        assert!(tight.stats.preemptions() > 0, "4-block arena must preempt");
        a.sort_by_key(|r| r.id);
        b.sort_by_key(|r| r.id);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens, "req {}: spec under pressure changed output", x.id);
        }
        let (live, ..) = tight.kv_usage();
        assert_eq!(live, 0, "blocks leaked through spec + preemption");
    }

    #[test]
    fn engine_from_store_serves_quantized_weights() {
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(4);
        let store = WeightStore::from_params(
            &params,
            &cfg,
            crate::quant::resolve("fp8_e3m4").unwrap(),
            4,
        )
        .unwrap();
        let mut e = Engine::from_store(&store, EngineConfig::default());
        e.enqueue(GenRequest::greedy(1, vec![2, 3, 4], 5)).unwrap();
        let out = e.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 5);
    }

    #[test]
    fn spawned_engine_serves_concurrent_clients() {
        let handle = tiny_engine(4, 0, 2).spawn();
        let mut joins = Vec::new();
        for c in 0..3u64 {
            let client = handle.client();
            joins.push(std::thread::spawn(move || {
                let mut lens = Vec::new();
                for k in 0..2u64 {
                    let id = c * 100 + k;
                    let resp = client
                        .generate(GenRequest::greedy(id, vec![1 + c as usize, 2], 3))
                        .unwrap();
                    assert_eq!(resp.id, id);
                    lens.push(resp.tokens.len());
                }
                lens
            }));
        }
        for j in joins {
            assert_eq!(j.join().unwrap(), vec![3, 3]);
        }
        let stats = handle.shutdown();
        assert_eq!(stats.completed(), 6);
    }

    #[test]
    fn temperature_sampling_reproducible_per_seed() {
        let mk = || {
            let mut e = tiny_engine(2, 0, 1);
            let req = GenRequest {
                id: 1,
                prompt: vec![4, 5],
                max_new_tokens: 8,
                temperature: 0.9,
                top_k: 20,
                seed: 1234,
                deadline_ms: None,
            };
            e.enqueue(req).unwrap();
            e.run_to_completion().remove(0).tokens
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn expired_deadline_returns_partial_response() {
        use crate::serve::protocol::FinishReason;
        let mut e = tiny_engine(2, 0, 1);
        // an already-expired deadline: the first step sweeps it out before
        // any wave runs, and the engine goes idle (no stuck request)
        let mut r = GenRequest::greedy(7, vec![3, 4, 5], 6);
        r.deadline_ms = Some(0);
        e.enqueue(r).unwrap();
        let out = e.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 7);
        assert_eq!(out[0].finish, FinishReason::Deadline);
        assert!(out[0].tokens.is_empty(), "never admitted: no tokens");
        assert!(e.is_idle());
        assert_eq!(e.stats.deadline_expired(), 1);
        let (live, ..) = e.kv_usage();
        assert_eq!(live, 0, "expiry leaked blocks");
        // a roomy deadline on the same engine completes normally
        let mut r = GenRequest::greedy(8, vec![3, 4, 5], 4);
        r.deadline_ms = Some(60_000);
        e.enqueue(r).unwrap();
        let out = e.run_to_completion();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].finish, FinishReason::Length);
        assert_eq!(out[0].tokens.len(), 4);
        assert_eq!(e.stats.deadline_expired(), 1, "unexpired deadline not counted");
    }

    #[test]
    fn tight_arena_preempts_and_still_completes_everything() {
        // 6 requests of 12+5 positions (3 blocks each) against a 4-block
        // arena: sequences must take turns via preemption, and every
        // completion must match an uncontended engine's output
        let mk_reqs = || -> Vec<GenRequest> {
            (0..6)
                .map(|id| {
                    let prompt: Vec<usize> =
                        (0..12).map(|k| (id as usize * 5 + k * 3) % 50).collect();
                    GenRequest::greedy(id, prompt, 6)
                })
                .collect()
        };
        let mut tight = tiny_engine(4, 4, 1);
        let mut roomy = tiny_engine(4, 0, 1);
        for r in mk_reqs() {
            tight.enqueue(r.clone()).unwrap();
            roomy.enqueue(r).unwrap();
        }
        let mut a = tight.run_to_completion();
        let mut b = roomy.run_to_completion();
        assert_eq!(a.len(), 6);
        assert!(
            tight.stats.preemptions() > 0,
            "4-block arena with 3-block sequences must preempt"
        );
        assert_eq!(roomy.stats.preemptions(), 0);
        a.sort_by_key(|r| r.id);
        b.sort_by_key(|r| r.id);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens, "req {}: preemption changed the output", x.id);
        }
        let (live, ..) = tight.kv_usage();
        assert_eq!(live, 0, "blocks leaked through preemption");
    }

    #[test]
    fn prefix_cache_reuses_shared_prompts() {
        let cfg = ModelConfig::tiny(Arch::Gpt2);
        let model = Transformer::new(cfg.clone());
        let params = model.init_params(3);
        let mk_engine = |prefix_cache: bool| {
            Engine::new(
                cfg.clone(),
                params.clone(),
                EngineConfig {
                    max_batch: 8,
                    kv_block: 4,
                    kv_blocks: 64,
                    prefill_chunk: 16,
                    prefix_cache,
                    threads: 1,
                    ..EngineConfig::default()
                },
            )
        };
        // 17 shared tokens: deliberately NOT block-aligned so adopters of
        // the cached full prompt append mid-block, exercising copy-on-write
        let shared: Vec<usize> = (0..17).map(|k| (k * 7 + 1) % 50).collect();
        let run = |prefix_cache: bool| -> (Engine, Vec<GenResponse>) {
            let mut e = mk_engine(prefix_cache);
            // warmup: one request with the bare shared prompt retires and
            // publishes its chain
            e.enqueue(GenRequest::greedy(100, shared.clone(), 4)).unwrap();
            let mut out = e.run_to_completion();
            // fan-out: 8 concurrent requests diverging after the prefix
            for id in 0..8u64 {
                let mut prompt = shared.clone();
                prompt.push(20 + id as usize);
                e.enqueue(GenRequest::greedy(id, prompt, 4)).unwrap();
            }
            out.extend(e.run_to_completion());
            (e, out)
        };
        let (cached, mut a) = run(true);
        let (plain, mut b) = run(false);
        assert_eq!(a.len(), 9);
        a.sort_by_key(|r| r.id);
        b.sort_by_key(|r| r.id);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tokens, y.tokens, "req {}: prefix sharing changed the output", x.id);
        }
        assert!(cached.stats.prefix_hits() >= 8, "fan-out admissions must hit the cached prefix");
        assert!(cached.stats.prefix_tokens_reused() >= 8 * 17);
        assert_eq!(plain.stats.prefix_hits(), 0);
        assert!(cached.cow_copies() > 0, "divergent mid-block tails must copy-on-write");
        // shared chains mean fewer live blocks for the same concurrent load
        assert!(
            cached.stats.mean_blocks_live() < plain.stats.mean_blocks_live(),
            "prefix sharing should lower block occupancy: {} vs {}",
            cached.stats.mean_blocks_live(),
            plain.stats.mean_blocks_live()
        );
    }
}
