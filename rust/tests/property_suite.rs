//! Cross-module property tests (mini-proptest from `gaussws::testing`):
//! invariants that span substrates rather than living inside one module.

use gaussws::config::schema::PqtMethod;
use gaussws::mx::transpose;
use gaussws::numerics::fpformat::{formats, FpFormat};
use gaussws::numerics::Rounding;
use gaussws::pqt::gaussws::{backward_bt, forward, pqn, NoiseGen};
use gaussws::pqt::PqtLinear;
use gaussws::quant::{fake_quantize, Codec, Geometry};
use gaussws::testing::prop::{check, Gen};

#[test]
fn prop_fp_cast_is_monotone() {
    // x <= y  =>  cast(x) <= cast(y), for every format
    check("fp cast monotone", 300, |g| {
        let fmt = *g.choose(&[
            formats::FP16,
            formats::FP8_E4M3,
            formats::FP8_E3M4,
            formats::FP6_E3M2,
            formats::FP4_E2M1,
            formats::FP12_E4M7,
        ]);
        let a = g.f64_in(-100.0, 100.0);
        let b = g.f64_in(-100.0, 100.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        if fmt.cast(lo) <= fmt.cast(hi) {
            Ok(())
        } else {
            Err(format!("{fmt:?}: cast({lo}) > cast({hi})"))
        }
    });
}

#[test]
fn prop_fp_cast_error_within_half_ulp() {
    check("fp cast error bound", 300, |g| {
        let fmt = *g.choose(&[formats::FP16, formats::FP8_E4M3, formats::FP12_E4M7]);
        let x = g.f64_in(-10.0, 10.0);
        let c = fmt.cast(x);
        if c.abs() >= fmt.max_finite() {
            return Ok(()); // saturated
        }
        let ulp = fmt.ulp(x);
        if (c - x).abs() <= 0.5 * ulp + 1e-18 {
            Ok(())
        } else {
            Err(format!("{fmt:?}: |{c} - {x}| > ulp/2 = {}", ulp / 2.0))
        }
    });
}

#[test]
fn prop_square_quant_commutes_with_transpose_for_any_block() {
    check("square quant transpose", 40, |g| {
        let rows = g.usize_in(1, 3) * 32;
        let cols = g.usize_in(1, 3) * 32;
        let block = *g.choose(&[8usize, 16, 32]);
        let w = g.normal_vec(rows * cols);
        let codec = Codec::Int { bits: g.i32_in(2, 8) as u32 };
        let sq = |w: &[f64], r: usize, c: usize| {
            fake_quantize(w, r, c, Geometry::Square { block }, &codec, Rounding::NearestEven, 0)
        };
        let q = sq(&w, rows, cols);
        let qt = transpose(&q.data, rows, cols);
        let wt = transpose(&w, rows, cols);
        let q2 = sq(&wt, cols, rows);
        if qt == q2.data {
            Ok(())
        } else {
            Err(format!("{rows}x{cols} block {block}"))
        }
    });
}

#[test]
fn prop_gaussws_backward_is_linear_in_g() {
    // backward_bt(a*g1 + g2) == a*backward_bt(g1) + backward_bt(g2)
    check("eq4 linearity", 25, |g| {
        let (m, n) = (64usize, 64usize);
        let w = g.normal_vec_f32(m * n);
        let bt = vec![g.f64_in(3.0, 8.0) as f32; 4];
        let mut what = vec![0f32; m * n];
        let st = forward(&w, m, n, 32, &bt, g.u64(), NoiseGen::Exact, &mut what);
        let g1 = g.normal_vec_f32(m * n);
        let g2 = g.normal_vec_f32(m * n);
        let a = g.f64_in(-2.0, 2.0) as f32;
        let combo: Vec<f32> = g1.iter().zip(&g2).map(|(x, y)| a * x + y).collect();
        let lhs = backward_bt(&st, &combo);
        let b1 = backward_bt(&st, &g1);
        let b2 = backward_bt(&st, &g2);
        for k in 0..lhs.len() {
            let rhs = a * b1[k] + b2[k];
            if (lhs[k] - rhs).abs() > 1e-3 * (1.0 + rhs.abs()) {
                return Err(format!("block {k}: {} vs {rhs}", lhs[k]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pqn_scale_invariance() {
    // scaling w by 2^k scales the PQN by exactly 2^k (power-of-two => the
    // blockwise max and bf16 arithmetic commute with the scaling)
    check("pqn scale invariance", 20, |g| {
        let (m, n) = (32usize, 32usize);
        let w = g.normal_vec_f32(m * n);
        let k = g.i32_in(-3, 3);
        let s = (k as f32).exp2();
        let ws: Vec<f32> = w.iter().map(|&x| x * s).collect();
        let bt = vec![5.0f32];
        let seed = g.u64();
        let mut buf = vec![0f32; m * n];
        let st1 = forward(&w, m, n, 32, &bt, seed, NoiseGen::Exact, &mut buf);
        let st2 = forward(&ws, m, n, 32, &bt, seed, NoiseGen::Exact, &mut buf);
        let p1 = pqn(&st1);
        let p2 = pqn(&st2);
        for i in 0..p1.len() {
            if (p1[i] * s - p2[i]).abs() > 1e-6 * s.abs() {
                return Err(format!("elem {i}: {} vs {}", p1[i] * s, p2[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_module_forward_preserves_w_where_noise_zero() {
    check("module zero-noise passthrough", 15, |g| {
        let l = PqtLinear::new("p", 64, 64, 32, PqtMethod::GaussWs, 6.0, 4.0);
        let w = g.normal_vec_f32(64 * 64);
        let mut what = vec![0f32; w.len()];
        let st = l.forward(&w, g.u64(), &mut what);
        if let gaussws::pqt::FwdState::Gauss(s) = &st {
            for i in 0..w.len() {
                if s.noise.get(i) == 0 {
                    let expect = gaussws::numerics::Bf16::from_f32(w[i]).to_f32();
                    if what[i] != expect {
                        return Err(format!("elem {i}"));
                    }
                }
            }
            Ok(())
        } else {
            Err("wrong state".into())
        }
    });
}

#[test]
fn prop_loader_batches_deterministic_and_in_vocab() {
    use gaussws::data::{Loader, SynthCorpus, SynthSpec};
    check("loader determinism", 10, |g| {
        let vocab = *g.choose(&[64usize, 256]);
        let corpus = SynthCorpus::generate(SynthSpec {
            vocab,
            len: 50_000,
            seed: g.u64(),
            ..Default::default()
        });
        let l = Loader::new(corpus, 2, 16, g.u64());
        let step = g.u64() % 1000;
        let a = l.batch_at(step);
        let b = l.batch_at(step);
        if a != b {
            return Err("non-deterministic batch".into());
        }
        if !a.x.iter().all(|&t| (t as usize) < vocab) {
            return Err("token out of vocab".into());
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_docs() {
    use gaussws::util::json::{arr, num, obj, s, Json};
    check("json roundtrip", 50, |g| {
        // build a random nested doc
        fn build(g: &mut Gen, depth: usize) -> Json {
            if depth == 0 || g.bool() {
                match g.i32_in(0, 2) {
                    0 => num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
                    1 => s(&format!("s{}", g.u32())),
                    _ => Json::Bool(g.bool()),
                }
            } else if g.bool() {
                arr((0..g.usize_in(0, 4)).map(|_| build(g, depth - 1)).collect())
            } else {
                obj((0..g.usize_in(0, 4))
                    .map(|i| (format!("k{i}"), build(g, depth - 1)))
                    .collect::<Vec<_>>()
                    .iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect())
            }
        }
        let doc = build(g, 3);
        let text = doc.to_string();
        let parsed = Json::parse(&text).map_err(|e| e.to_string())?;
        if parsed == doc {
            Ok(())
        } else {
            Err(format!("roundtrip mismatch: {text}"))
        }
    });
}

#[test]
fn prop_bf16_cast_idempotent_and_exact_on_grid() {
    use gaussws::numerics::Bf16;
    check("bf16 idempotent", 200, |g| {
        let x = (g.f64_in(-1e4, 1e4)) as f32;
        let once = Bf16::from_f32(x).to_f32();
        let twice = Bf16::from_f32(once).to_f32();
        if once.to_bits() == twice.to_bits() {
            Ok(())
        } else {
            Err(format!("{x}"))
        }
    });
}

#[test]
fn prop_packed_codes_roundtrip_any_width() {
    use gaussws::quant::{packed_bytes, PackedCodes};
    // push/get/iter/set and the byte-serialization roundtrip agree for
    // every width 2..=16 — including the non-byte-aligned widths whose
    // codes straddle byte boundaries (3, 5, 6, 7, ...)
    check("packed codes roundtrip", 80, |g| {
        let bits = g.usize_in(2, 16) as u32;
        let len = g.usize_in(0, 100);
        let mask = if bits == 16 { u32::from(u16::MAX) } else { (1u32 << bits) - 1 };
        let codes: Vec<u16> = (0..len).map(|_| (g.u32() & mask) as u16).collect();
        let mut pc = PackedCodes::new(bits);
        for &c in &codes {
            pc.push(c);
        }
        if pc.len() != len || pc.byte_len() != packed_bytes(bits, len) {
            return Err(format!("bits {bits} len {len}: wrong size accounting"));
        }
        for (i, &c) in codes.iter().enumerate() {
            if pc.get(i) != c {
                return Err(format!("bits {bits} len {len}: get({i}) != pushed code"));
            }
        }
        if pc.iter().collect::<Vec<u16>>() != codes {
            return Err(format!("bits {bits} len {len}: iter() diverged from get()"));
        }
        let back = PackedCodes::from_bytes(bits, len, pc.as_bytes().to_vec())
            .map_err(|e| format!("bits {bits} len {len}: {e:#}"))?;
        if back != pc {
            return Err(format!("bits {bits} len {len}: byte roundtrip changed codes"));
        }
        // a random in-place overwrite must leave every neighbor intact
        if len > 0 {
            let i = g.usize_in(0, len - 1);
            let v = (g.u32() & mask) as u16;
            pc.set(i, v);
            for (j, &c) in codes.iter().enumerate() {
                let want = if j == i { v } else { c };
                if pc.get(j) != want {
                    return Err(format!("bits {bits}: set({i}) corrupted slot {j}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn lut_decode_matches_codec_decode_for_every_registered_codec() {
    use gaussws::quant::{DequantLut, Registry};
    // exhaustive, not sampled: every packed codec in the registry, every
    // one of its 2^bits codes, compared bit-for-bit (f64::to_bits so NaN
    // payloads and signed zeros count too)
    let mut checked = 0;
    for scheme in Registry::global().schemes() {
        let Some(lut) = DequantLut::for_codec(&scheme.codec) else {
            continue; // f32 passthrough has no code table
        };
        assert_eq!(lut.len(), 1usize << scheme.codec.bits_per_elem(), "{}", scheme.label());
        // usize loop: `lut.len() as u16` would wrap to 0 for 16-bit codecs
        for code in 0..lut.len() {
            let code = code as u16;
            assert_eq!(
                lut.decode(code).to_bits(),
                scheme.codec.decode(code).to_bits(),
                "{}: code {code} decodes differently via the LUT",
                scheme.label()
            );
        }
        checked += 1;
    }
    assert!(checked >= 10, "only {checked} packed codecs in the registry?");
}

#[test]
fn prop_fused_kv_reads_match_mirror_for_every_registered_scheme() {
    use gaussws::nn::kv::{KvQuant, PagedKv};
    use gaussws::quant::Registry;
    use gaussws::testing::fuzz::model_under_test;
    // same packed codes, two read paths: the fused dequant-dot kernels vs
    // the opt-in f32 decode mirror must produce bit-identical logits for
    // every scheme the KV arena can host (blockwise or passthrough)
    let (model, params) = model_under_test();
    let tokens: Vec<usize> = (0..10).map(|k| (k * 11 + 3) % 50).collect();
    let mut hosted = 0;
    for scheme in Registry::global().schemes() {
        let Ok(quant) = KvQuant::new(scheme.clone(), model.cfg.d_model, 0xBEEF) else {
            continue; // elementwise geometries are not hostable — skip
        };
        let label = scheme.label().to_string();
        let mut fused = PagedKv::new_quantized(&model.cfg, 4, tokens.len(), quant.clone());
        let mut mirrored = PagedKv::new_quantized(&model.cfg, 4, tokens.len(), quant.with_mirror());
        for &t in &tokens {
            let a = model.decode_step(&params, t, &mut fused);
            let b = model.decode_step(&params, t, &mut mirrored);
            assert_eq!(a.len(), b.len(), "{label}");
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{label}: fused logits diverged from the mirror"
                );
            }
        }
        hosted += 1;
    }
    assert!(hosted >= 10, "only {hosted} KV-hostable schemes in the registry?");
}

#[test]
fn prop_fpformat_enumeration_closed_under_cast() {
    // every enumerated value is a fixed point of cast (tiny formats)
    check("enumeration fixed points", 6, |g| {
        let fmt: FpFormat = *g.choose(&[formats::FP4_E2M1, formats::FP6_E3M2, formats::FP6_E2M3]);
        for v in fmt.enumerate_non_negative() {
            if fmt.cast(v) != v {
                return Err(format!("{fmt:?}: {v}"));
            }
        }
        Ok(())
    });
}
