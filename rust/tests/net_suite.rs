//! Integration suite for the TCP serving front end (`serve::net`): a real
//! loopback server per test, real client sockets, and the in-process
//! engine as the behavioural reference.
//!
//! Covers: bit-identical outputs vs the in-process engine on a seeded
//! workload, malformed-frame and malformed-JSON handling (error frames;
//! connection lifetime semantics), strict-parse error frames, deadline
//! expiry over the wire (per-request and server-default), deterministic
//! shed-with-retry backpressure, drain-under-load (no admitted response is
//! lost, live-block gauge ends at zero), duplicate in-flight id rejection,
//! and connection-registry hygiene (closed connections are reaped, not
//! accumulated for the server's lifetime; accepted/closed counters
//! converge at quiescence).

use gaussws::config::schema::{Arch, ModelConfig};
use gaussws::load::{run, Dist, Driver, WorkloadSpec};
use gaussws::nn::transformer::Transformer;
use gaussws::serve::net::frame;
use gaussws::serve::protocol::parse_reply;
use gaussws::serve::{
    Engine, EngineConfig, FinishReason, GenRequest, NetClient, NetServer, NetServerConfig,
};
use gaussws::util::json::Json;
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

fn tiny_engine(ecfg: EngineConfig) -> Engine {
    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(7);
    Engine::new(cfg, params, ecfg)
}

fn base_cfg() -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        kv_block: 8,
        kv_blocks: 0,
        prefill_chunk: 4,
        prefix_cache: false,
        threads: 1,
        trace: true,
        ..EngineConfig::default()
    }
}

#[test]
fn loopback_is_bit_identical_to_in_process_engine() {
    // the same seeded workload through Driver::Direct and Driver::Tcp must
    // produce identical token streams (greedy serving is
    // schedule-independent, so transport cannot matter)
    let spec = WorkloadSpec::new("net-conformance")
        .clients(3)
        .requests(12)
        .prompt_len(Dist::Uniform { lo: 2, hi: 10 })
        .max_new(Dist::Uniform { lo: 2, hi: 6 })
        .shared_prefix(8, 0.5)
        .seed(44);
    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(7);
    let ecfg = EngineConfig { prefix_cache: true, ..base_cfg() };
    let direct = run(&spec, cfg.clone(), params.clone(), ecfg.clone(), Driver::Direct).unwrap();
    let tcp = run(&spec, cfg, params, ecfg, Driver::Tcp(NetServerConfig::default())).unwrap();
    assert_eq!(direct.responses.len(), 12);
    assert_eq!(tcp.responses.len(), 12, "tcp run lost responses");
    assert_eq!(tcp.failed, 0);
    for (a, b) in direct.responses.iter().zip(tcp.responses.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "req {}: transport changed the tokens", a.id);
    }
    // drain leaves no KV blocks live, even with the prefix cache on
    assert_eq!(tcp.stats.blocks_live_now(), 0.0, "tcp drain leaked blocks");
    let reg = tcp.stats.registry();
    assert_eq!(reg.counter("net.requests_admitted").get(), 12);
    assert_eq!(reg.counter("net.responses_sent").get(), 12);
    assert_eq!(reg.counter("net.connections_accepted").get(), 3);
}

#[test]
fn malformed_json_gets_error_frame_and_connection_survives() {
    let server = NetServer::bind("127.0.0.1:0", tiny_engine(base_cfg()), NetServerConfig::default())
        .unwrap();
    let stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // not JSON at all: one permanent error frame, connection stays open
    frame::write_frame(&mut writer, "this is not json").unwrap();
    let payload = frame::read_frame(&mut reader).unwrap().expect("error frame");
    let err = parse_reply(&Json::parse(&payload).unwrap()).unwrap().unwrap_err();
    assert!(err.error.contains("invalid JSON"), "{}", err.error);
    assert_eq!(err.retry_after_ms, None, "parse errors are permanent");

    // strict-parse failure: per-field errors, echoing the id, still open
    frame::write_frame(&mut writer, r#"{"id": 9, "prompt": []}"#).unwrap();
    let payload = frame::read_frame(&mut reader).unwrap().expect("error frame");
    let err = parse_reply(&Json::parse(&payload).unwrap()).unwrap().unwrap_err();
    assert_eq!(err.id, Some(9));
    assert!(err.error.contains("prompt"), "{}", err.error);
    assert!(err.error.contains("max_new_tokens"), "{}", err.error);

    // the same connection still serves a valid request afterwards
    let req = GenRequest::greedy(1, vec![3, 4], 3);
    frame::write_frame(&mut writer, &req.to_json().to_string()).unwrap();
    let payload = frame::read_frame(&mut reader).unwrap().expect("response frame");
    let resp = parse_reply(&Json::parse(&payload).unwrap()).unwrap().unwrap();
    assert_eq!(resp.id, 1);
    assert_eq!(resp.tokens.len(), 3);

    let stats = server.shutdown();
    let reg = stats.registry();
    assert_eq!(reg.counter("net.frames_bad").get(), 2);
    assert_eq!(reg.counter("net.requests_admitted").get(), 1);
}

#[test]
fn garbage_framing_gets_error_frame_and_closes_connection() {
    let server = NetServer::bind("127.0.0.1:0", tiny_engine(base_cfg()), NetServerConfig::default())
        .unwrap();
    let stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    // a header that is not `<len> `: framing violation
    writer.write_all(b"hello world\n").unwrap();
    writer.flush().unwrap();
    let payload = frame::read_frame(&mut reader).unwrap().expect("error frame");
    let err = parse_reply(&Json::parse(&payload).unwrap()).unwrap().unwrap_err();
    assert!(err.error.contains("framing"), "{}", err.error);
    // the reader abandoned the connection: no further frame is ever
    // answered, and after the drain the socket reads EOF
    frame::write_frame(&mut writer, "0 \n").unwrap();
    let stats = server.shutdown();
    assert_eq!(frame::read_frame(&mut reader).unwrap(), None, "expected EOF");
    assert_eq!(stats.registry().counter("net.frames_bad").get(), 1);
    assert_eq!(stats.registry().counter("net.requests_admitted").get(), 0);
}

#[test]
fn partial_frame_then_eof_closes_cleanly() {
    let server = NetServer::bind("127.0.0.1:0", tiny_engine(base_cfg()), NetServerConfig::default())
        .unwrap();
    {
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        // declare 100 payload bytes, deliver 10, hang up
        stream.write_all(b"100 {\"id\": 3,").unwrap();
        stream.flush().unwrap();
    } // dropped: EOF mid-frame on the server side
    // give the reader thread a beat to observe the EOF
    std::thread::sleep(Duration::from_millis(50));
    let stats = server.shutdown();
    let reg = stats.registry();
    assert_eq!(reg.counter("net.connections_accepted").get(), 1);
    assert_eq!(reg.counter("net.connections_closed").get(), 1);
    assert_eq!(reg.counter("net.frames_bad").get(), 1, "partial frame counts as bad");
    assert_eq!(reg.counter("net.requests_admitted").get(), 0);
}

#[test]
fn per_request_deadline_expires_over_the_wire() {
    let server = NetServer::bind("127.0.0.1:0", tiny_engine(base_cfg()), NetServerConfig::default())
        .unwrap();
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let mut req = GenRequest::greedy(5, vec![2, 3, 4], 6);
    req.deadline_ms = Some(0); // already expired on arrival
    let resp = client.generate(&req).unwrap();
    assert_eq!(resp.id, 5);
    assert_eq!(resp.finish, FinishReason::Deadline);
    assert!(resp.tokens.is_empty(), "never admitted: no tokens");
    // a roomy deadline completes normally on the same connection
    let mut req = GenRequest::greedy(6, vec![2, 3, 4], 4);
    req.deadline_ms = Some(60_000);
    let resp = client.generate(&req).unwrap();
    assert_eq!(resp.finish, FinishReason::Length);
    assert_eq!(resp.tokens.len(), 4);
    let stats = server.shutdown();
    assert_eq!(stats.deadline_expired(), 1);
    assert_eq!(stats.blocks_live_now(), 0.0);
}

#[test]
fn server_default_deadline_applies_to_bare_requests() {
    let cfg = NetServerConfig { default_deadline_ms: Some(0), ..NetServerConfig::default() };
    let server = NetServer::bind("127.0.0.1:0", tiny_engine(base_cfg()), cfg).unwrap();
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let resp = client.generate(&GenRequest::greedy(1, vec![4, 5], 5)).unwrap();
    assert_eq!(resp.finish, FinishReason::Deadline, "server default deadline must apply");
    let stats = server.shutdown();
    assert_eq!(stats.deadline_expired(), 1);
}

#[test]
fn overload_sheds_with_retry_hint() {
    // deterministic overload: a 2-block arena whose prefix cache retains
    // one block after the first request retires — a follow-up needing 2
    // blocks exceeds the free headroom, and max_pending 0 forbids queueing
    let ecfg = EngineConfig { kv_blocks: 2, prefix_cache: true, ..base_cfg() };
    let net_cfg = NetServerConfig {
        max_pending: 0,
        retry_after_ms: 17,
        ..NetServerConfig::default()
    };
    let server = NetServer::bind("127.0.0.1:0", tiny_engine(ecfg), net_cfg).unwrap();
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    // warmup: 8-token prompt (one full block) retires into the prefix index
    let resp = client.generate(&GenRequest::greedy(1, vec![1; 8], 2)).unwrap();
    assert_eq!(resp.tokens.len(), 2);
    // needs 2 blocks; 1 is pinned by the cached prefix => shed
    let req = GenRequest::greedy(2, vec![2; 8], 9);
    client.send(&req).unwrap();
    let err = client.recv().unwrap().expect_err("must be shed");
    assert_eq!(err.id, Some(2));
    assert_eq!(err.retry_after_ms, Some(17), "shed errors carry the configured hint");
    assert!(err.error.contains("overloaded"), "{}", err.error);
    let stats = server.shutdown();
    assert_eq!(stats.registry().counter("net.requests_shed").get(), 1);
    assert_eq!(stats.completed(), 1);
    assert_eq!(stats.blocks_live_now(), 0.0, "drain must clear the pinned prefix block");
}

#[test]
fn drain_under_load_loses_no_admitted_responses() {
    let server = NetServer::bind("127.0.0.1:0", tiny_engine(base_cfg()), NetServerConfig::default())
        .unwrap();
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    for id in 0..4u64 {
        client.send(&GenRequest::greedy(id, vec![1 + id as usize, 2, 3], 12)).unwrap();
    }
    // let the frames reach the engine thread, then drain mid-generation
    std::thread::sleep(Duration::from_millis(50));
    let collector = std::thread::spawn(move || {
        let mut got = Vec::new();
        for _ in 0..4 {
            got.push(client.recv().unwrap().expect("admitted requests must complete"));
        }
        // after the drain the server closes the socket
        assert!(client.recv().is_err(), "expected EOF after drain");
        got
    });
    let stats = server.shutdown();
    let mut got = collector.join().unwrap();
    got.sort_by_key(|r| r.id);
    assert_eq!(got.len(), 4, "drain lost responses");
    for (id, r) in got.iter().enumerate() {
        assert_eq!(r.id, id as u64);
        assert_eq!(r.tokens.len(), 12);
    }
    assert_eq!(stats.completed(), 4);
    assert_eq!(stats.blocks_live_now(), 0.0, "live-block gauge must read zero after drain");
    assert_eq!(stats.registry().counter("net.responses_sent").get(), 4);
}

#[test]
fn connect_disconnect_cycles_reap_the_conn_registry() {
    // regression: the open-connection registry used to push one TcpStream
    // clone per accepted connection and only drain at shutdown — a
    // long-lived server leaked one fd per connection ever accepted. Now
    // each reader reaps its own entry on exit, so after N full
    // connect/serve/disconnect cycles the registry must be empty and the
    // accepted/closed counters must agree.
    const CYCLES: u64 = 8;
    let server = NetServer::bind("127.0.0.1:0", tiny_engine(base_cfg()), NetServerConfig::default())
        .unwrap();
    for id in 0..CYCLES {
        let mut client = NetClient::connect(server.local_addr()).unwrap();
        let resp = client.generate(&GenRequest::greedy(id, vec![1 + id as usize, 2], 2)).unwrap();
        assert_eq!(resp.id, id);
        assert_eq!(resp.tokens.len(), 2);
    } // client drops: server reader sees EOF, reaps its registry entry
    // reaping is asynchronous (reader threads observe the EOF on their own
    // schedule): poll until the registry drains, bounded by a deadline
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while server.open_connections() > 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(
        server.open_connections(),
        0,
        "closed connections must be reaped from the registry, not accumulated"
    );
    let stats = server.shutdown();
    let reg = stats.registry();
    assert_eq!(reg.counter("net.connections_accepted").get(), CYCLES);
    assert_eq!(
        reg.counter("net.connections_closed").get(),
        CYCLES,
        "every accepted connection must be counted closed at quiescence"
    );
    assert_eq!(reg.counter("net.accept_clone_failures").get(), 0);
    assert_eq!(stats.completed(), CYCLES as usize);
}

#[test]
fn duplicate_in_flight_id_is_rejected() {
    let server = NetServer::bind("127.0.0.1:0", tiny_engine(base_cfg()), NetServerConfig::default())
        .unwrap();
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    // a long-running request, then a duplicate id while it is in flight
    let slow = GenRequest::greedy(7, vec![2, 3], 40);
    client.send(&slow).unwrap();
    client.send(&GenRequest::greedy(7, vec![4, 5], 2)).unwrap();
    let mut saw_dup_error = false;
    let mut saw_response = false;
    for _ in 0..2 {
        match client.recv().unwrap() {
            Ok(resp) => {
                assert_eq!(resp.id, 7);
                assert_eq!(resp.tokens.len(), 40, "the original request must complete");
                saw_response = true;
            }
            Err(err) => {
                assert_eq!(err.id, Some(7));
                assert!(err.error.contains("duplicate"), "{}", err.error);
                saw_dup_error = true;
            }
        }
    }
    assert!(saw_response, "original request lost");
    assert!(saw_dup_error, "duplicate id was not rejected");
    let stats = server.shutdown();
    assert_eq!(stats.completed(), 1);
}
