//! Cross-language integration: the AOT artifacts (jax/Pallas-lowered HLO)
//! executed through the PJRT runtime must agree with the pure-rust
//! implementations of the same math. Requires `make artifacts`.

use gaussws::numerics::Bf16;
use gaussws::prng::Philox4x32;
use gaussws::runtime::{HostTensor, Runtime};

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::new("artifacts") {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping (artifacts not built): {e}");
            None
        }
    }
}

/// Mirror of prng::bitwise::planes_fast on plain u32 words (the kernel and
/// the rust generator share this construction).
fn planes_fast_ref(r: &[u32; 4]) -> Vec<i32> {
    let a = r[1];
    let b = r[2];
    let c = r[3];
    let chain = b
        & b.rotate_left(7)
        & b.rotate_left(13)
        & b.rotate_left(22)
        & c
        & c.rotate_left(5)
        & c.rotate_left(17)
        & c.rotate_left(26);
    let mag2 = (a | a.rotate_left(11)) & chain;
    let mag1 =
        (a.rotate_left(3) | b.rotate_left(29)) & (c.rotate_left(9) | a.rotate_left(19)) & b.rotate_left(16) & !mag2;
    let sign = r[0];
    (0..32)
        .map(|lane| {
            let s = (sign >> lane) & 1;
            let m = ((mag1 >> lane) & 1) as i32 + 2 * ((mag2 >> lane) & 1) as i32;
            if s == 1 {
                -m
            } else {
                m
            }
        })
        .collect()
}

#[test]
fn noise_kernel_matches_rust_bit_construction() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let spec = rt.manifest.get("op.noise_bitwise").unwrap().clone();
    let groups = spec.inputs[0].shape[0];
    let mut g = Philox4x32::new(42);
    let mut bits = vec![0u32; groups * 4];
    g.fill_u32(&mut bits);
    let out = rt
        .execute("op.noise_bitwise", &[HostTensor::U32(bits.clone())])
        .unwrap();
    let vals = out[0].as_f32().unwrap();
    assert_eq!(vals.len(), groups * 32);
    for grp in 0..groups.min(256) {
        let words = [bits[grp * 4], bits[grp * 4 + 1], bits[grp * 4 + 2], bits[grp * 4 + 3]];
        let expect = planes_fast_ref(&words);
        for lane in 0..32 {
            assert_eq!(
                vals[grp * 32 + lane] as i32,
                expect[lane],
                "group {grp} lane {lane}"
            );
        }
    }
}

#[test]
fn sampling_kernel_matches_rust_formula() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let spec = rt.manifest.get("op.gaussws_sample").unwrap().clone();
    let (m, n) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let (gm, gn) = (m / 32, n / 32);

    let mut g = Philox4x32::new(7);
    let w: Vec<f32> = (0..m * n).map(|_| (g.next_f32() - 0.5) * 2.0).collect();
    let bt: Vec<f32> = (0..gm * gn).map(|_| 3.0 + g.next_f32() * 5.0).collect();
    // noise values in {-2..2}
    let noise: Vec<f32> = (0..m * n).map(|_| ((g.next_u32() % 5) as i32 - 2) as f32).collect();

    let out = rt
        .execute(
            "op.gaussws_sample",
            &[
                HostTensor::F32(w.clone()),
                HostTensor::F32(bt.clone()),
                HostTensor::F32(noise.clone()),
            ],
        )
        .unwrap();
    let what = out[0].as_f32().unwrap();

    // rust-side amax per 32x32 block
    let amax = gaussws::mx::block_absmax_f32(&w, m, n, 32);
    for r in 0..m {
        for c in 0..n {
            let i = r * n + c;
            let blk = (r / 32) * gn + c / 32;
            let scale = amax[blk] * (1.0 - bt[blk]).exp2();
            let expect = Bf16::from_f32(w[i] + noise[i] * scale).to_f32();
            assert_eq!(what[i], expect, "({r},{c})");
        }
    }
}

#[test]
fn box_muller_kernel_distribution() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let spec = rt.manifest.get("op.noise_boxmuller").unwrap().clone();
    let groups = spec.inputs[0].shape[0];
    let mut g = Philox4x32::new(3);
    let mut bits = vec![0u32; groups * 32];
    g.fill_u32(&mut bits);
    let out = rt.execute("op.noise_boxmuller", &[HostTensor::U32(bits)]).unwrap();
    let vals = out[0].as_f32().unwrap();
    let n = vals.len() as f64;
    let p0 = vals.iter().filter(|&&v| v == 0.0).count() as f64 / n;
    // exact rounded normal: Pr(0) = P(|N|<1) ~ 0.6827
    assert!((p0 - 0.6827).abs() < 0.02, "p0={p0}");
}

#[test]
fn artifact_signature_mismatches_are_rejected() {
    let Some(mut rt) = runtime_or_skip() else { return };
    // wrong input count
    assert!(rt.execute("op.noise_bitwise", &[]).is_err());
    // wrong dtype
    let spec = rt.manifest.get("op.noise_bitwise").unwrap().clone();
    let numel = spec.inputs[0].numel();
    assert!(rt
        .execute("op.noise_bitwise", &[HostTensor::F32(vec![0.0; numel])])
        .is_err());
    // unknown artifact
    assert!(rt.execute("op.does_not_exist", &[]).is_err());
}
