//! Telemetry integration suite: registry exactness under concurrency,
//! deterministic exposition, Chrome trace JSONL round-trips through
//! `util::json`, engine trace-span structure (including preemption), and
//! the histogram-vs-exact-percentile property.

use gaussws::config::schema::{Arch, ModelConfig};
use gaussws::serve::{Engine, EngineConfig, GenRequest};
use gaussws::telemetry::{check_well_nested, Histogram, Phase, Registry};
use gaussws::testing::prop::{check, Gen};
use gaussws::util::json::Json;
use gaussws::util::stats::percentile_nearest_rank;

// ---- registry -----------------------------------------------------------

#[test]
fn counters_are_exact_under_contention() {
    let reg = Registry::new();
    let threads = 8;
    let per_thread = 20_000u64;
    std::thread::scope(|s| {
        for _ in 0..threads {
            let c = reg.counter("hits");
            let g = reg.gauge("level");
            let h = reg.histogram("lat");
            s.spawn(move || {
                for i in 0..per_thread {
                    c.inc();
                    g.set(i as f64);
                    h.record(1.0 + (i % 7) as f64);
                }
            });
        }
    });
    assert_eq!(reg.counter("hits").get(), threads * per_thread);
    assert_eq!(reg.histogram("lat").count(), threads * per_thread);
    assert!(reg.gauge("level").get() < per_thread as f64);
}

#[test]
fn exposition_is_deterministic() {
    let build = || {
        let reg = Registry::new();
        reg.counter("b.count").add(3);
        reg.counter("a.count").inc();
        reg.gauge("z.gauge").set(1.5);
        let h = reg.histogram("m.hist");
        for v in [0.1, 0.2, 0.4, 0.8] {
            h.record(v);
        }
        reg
    };
    let (x, y) = (build(), build());
    assert_eq!(x.snapshot_json().to_string(), y.snapshot_json().to_string());
    assert_eq!(x.prometheus_text(), y.prometheus_text());
    // repeated exposition of the same registry is stable too
    assert_eq!(x.snapshot_json().to_string(), x.snapshot_json().to_string());
    // names come out sorted (BTreeMap order), so diffs are meaningful
    let names = x.names();
    let mut sorted = names.clone();
    sorted.sort();
    assert_eq!(names, sorted);
}

// ---- engine traces ------------------------------------------------------

fn traced_engine(kv_blocks: usize, max_batch: usize) -> Engine {
    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = gaussws::nn::transformer::Transformer::new(cfg.clone());
    let params = model.init_params(7);
    Engine::new(
        cfg,
        params,
        EngineConfig {
            max_batch,
            kv_block: 2,
            kv_blocks,
            prefill_chunk: 3,
            threads: 1,
            trace: true,
            ..EngineConfig::default()
        },
    )
}

fn run_requests(e: &mut Engine, n: usize) {
    for id in 0..n {
        let prompt: Vec<usize> = (0..5).map(|k| (id * 7 + k * 3 + 1) % 50).collect();
        e.enqueue(GenRequest::greedy(id as u64, prompt, 4)).unwrap();
    }
    let done = e.run_to_completion();
    assert_eq!(done.len(), n);
}

#[test]
fn trace_jsonl_round_trips_through_util_json() {
    let mut e = traced_engine(0, 4);
    run_requests(&mut e, 4);
    let t = e.stats.trace().expect("tracing was enabled");
    assert!(!t.is_empty());
    let lines: Vec<&str> = t.to_json_lines().lines().collect();
    assert_eq!(lines.len(), t.len());
    for line in lines {
        let v = Json::parse(line).expect("each trace line is standalone JSON");
        assert!(v.get("name").as_str().is_some());
        assert!(matches!(v.get("ph").as_str(), Some("B" | "E" | "X" | "i" | "C")));
        assert_eq!(v.get("pid").as_f64(), Some(1.0));
        assert!(v.get("ts").as_f64().is_some());
    }
    // and the same bytes land on disk via write_jsonl
    let dir = std::env::temp_dir().join(format!("gaussws_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.jsonl");
    t.write_jsonl(path.to_str().unwrap()).unwrap();
    assert_eq!(std::fs::read_to_string(&path).unwrap(), t.to_json_lines());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn request_spans_cover_the_lifecycle() {
    let mut e = traced_engine(0, 4);
    run_requests(&mut e, 4);
    let events = e.stats.trace_events();
    check_well_nested(events).expect("trace must be well-nested");
    let count = |name: &str, ph: Phase| {
        events.iter().filter(|ev| ev.name == name && ev.ph == ph).count()
    };
    assert_eq!(count("request", Phase::Begin), 4);
    assert_eq!(count("request", Phase::End), 4);
    assert_eq!(count("resident", Phase::Begin), 4);
    // 5-token prompts with a 3-token prefill chunk → ≥ 1 prefill span and
    // ≥ 3 decode spans (4 new tokens, the first sampled off prefill) each
    assert!(count("prefill", Phase::Complete) >= 4);
    assert!(count("decode", Phase::Complete) >= 4 * 3);
    // live-block counter samples track reserve/release over time
    assert!(count("kv_blocks_live", Phase::Counter) > 0);
    assert_eq!(count("preempt", Phase::Instant), 0);
}

#[test]
fn preempted_requests_get_two_residencies() {
    // same contention geometry as the engine's preemption test: 6 requests
    // of 12+5 positions (3 blocks each at kv_block 8) vs a 4-block arena
    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = gaussws::nn::transformer::Transformer::new(cfg.clone());
    let params = model.init_params(3);
    let mut e = Engine::new(
        cfg,
        params,
        EngineConfig {
            max_batch: 4,
            kv_block: 8,
            kv_blocks: 4,
            prefill_chunk: 4,
            prefix_cache: false,
            threads: 1,
            trace: true,
            ..EngineConfig::default()
        },
    );
    for id in 0..6u64 {
        let prompt: Vec<usize> = (0..12).map(|k| (id as usize * 5 + k * 3) % 50).collect();
        e.enqueue(GenRequest::greedy(id, prompt, 6)).unwrap();
    }
    assert_eq!(e.run_to_completion().len(), 6);
    assert!(e.stats.preemptions() > 0, "tight arena must preempt");
    let events = e.stats.trace_events();
    check_well_nested(events).expect("preempted trace must still be well-nested");
    let residencies =
        events.iter().filter(|ev| ev.name == "resident" && ev.ph == Phase::Begin).count();
    let preempts =
        events.iter().filter(|ev| ev.name == "preempt" && ev.ph == Phase::Instant).count();
    assert_eq!(preempts, e.stats.preemptions());
    // every preemption re-admits, so residencies = requests + preemptions
    assert_eq!(residencies, 6 + preempts);
}

#[test]
fn serve_registry_and_trainer_registry_share_exposition_shape() {
    let mut e = traced_engine(0, 4);
    run_requests(&mut e, 4);
    e.clear_prefix_cache(); // release cached chains so the live gauge reads 0
    let text = e.stats.registry().prometheus_text();
    assert!(text.contains("gaussws_serve_requests_completed 4"));
    assert!(text.contains("gaussws_serve_kv_blocks_live 0"));
    assert!(text.contains("gaussws_serve_latency_total_s"));
    let snap = e.stats.registry().snapshot_json();
    assert_eq!(snap.get("serve.requests_completed").as_f64(), Some(4.0));
    assert!(snap.get("serve.latency_total_s").get("p95").as_f64().is_some());
}

// ---- histogram property -------------------------------------------------

#[test]
fn histogram_quantiles_track_exact_percentiles() {
    check("hist quantile within one bucket of exact", 60, |g: &mut Gen| {
        let n = g.usize_in(1, 400);
        let h = Histogram::new();
        let mut xs = Vec::with_capacity(n);
        // span several octaves so many buckets are exercised
        for _ in 0..n {
            let v = g.f64_in(1e-4, 50.0);
            h.record(v);
            xs.push(v);
        }
        for &p in &[50.0, 95.0, 99.0] {
            let exact = percentile_nearest_rank(&xs, p);
            let approx = h.quantile(p / 100.0);
            let width = gaussws::telemetry::hist::bucket_width(exact);
            if (approx - exact).abs() > width {
                return Err(format!(
                    "n={n} p={p}: histogram {approx} vs exact {exact} (bucket width {width})"
                ));
            }
        }
        Ok(())
    });
}
