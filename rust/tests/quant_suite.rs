//! Unified-codec acceptance suite for the `quant` subsystem:
//!
//! * every registered packed scheme encode→decode round-trips *exactly*;
//! * GWQS2 snapshots written through `QuantScheme` dequantize bit-for-bit
//!   identical to a direct square-blockwise `fake_quantize` of the same
//!   weights for every registered FP format — the serving store inherits
//!   the Table C.1 fidelity claims through the one shared engine;
//! * stochastic rounding is unbiased in expectation (mean error → 0 over
//!   many draws) for both FP and INT codecs.

use gaussws::config::schema::{Arch, ModelConfig};
use gaussws::nn::transformer::{Params, Transformer};
use gaussws::numerics::Rounding;
use gaussws::quant::{fake_quantize, Codec, Geometry, QuantScheme, Registry, Scheme};
use gaussws::testing::prop::{check, Gen};

/// Every registered scheme with a packed codec must encode→decode exactly.
#[test]
fn every_registered_scheme_roundtrips_codes_exactly() {
    for scheme in Registry::global().schemes() {
        match scheme.codec {
            Codec::F32 => continue, // raw tensors, no codes
            Codec::Fp(fmt) => {
                for v in fmt.enumerate_non_negative() {
                    for signed in [v, -v] {
                        let code = scheme.encode(signed);
                        assert!(
                            (code as u32) < (1u32 << fmt.total_bits()),
                            "{}: code {code} wider than {} bits",
                            scheme.label(),
                            fmt.total_bits()
                        );
                        assert_eq!(
                            scheme.decode(code),
                            signed,
                            "{}: {signed} -> {code}",
                            scheme.label()
                        );
                    }
                }
            }
            Codec::Int { bits } => {
                let m = (1i64 << (bits - 1)) - 1;
                for v in -m..=m {
                    let code = scheme.encode(v as f64);
                    assert_eq!(scheme.decode(code), v as f64, "{}: {v}", scheme.label());
                }
            }
        }
    }
}

/// Random fake-quantized values must survive the pack→unpack codec hop at
/// the block scale, for every registered square-blockwise scheme.
#[test]
fn prop_quantized_values_roundtrip_through_codes() {
    check("scheme codes roundtrip at scale", 20, |g: &mut Gen| {
        for scheme in Registry::global().schemes() {
            if !scheme.codec.is_packed() || !matches!(scheme.geometry, Geometry::Square { .. }) {
                continue;
            }
            let (rows, cols) = (g.usize_in(1, 40), g.usize_in(1, 40));
            let w = g.normal_vec(rows * cols);
            let q = scheme.quantize(&w, rows, cols, g.u64());
            let block = scheme.block().unwrap();
            let grid_c = cols.div_ceil(block);
            for (i, &v) in q.data.iter().enumerate() {
                let (r, c) = (i / cols, i % cols);
                let s = q.scales[(r / block) * grid_c + c / block];
                let back = scheme.decode(scheme.encode(v / s)) * s;
                if back != v {
                    return Err(format!("{}: elem {i}: {v} -> {back}", scheme.label()));
                }
            }
        }
        Ok(())
    });
}

/// The acceptance criterion: a GWQS2 snapshot written via `QuantScheme`
/// must dequantize bit-for-bit identical to a direct square-blockwise RNE
/// `fake_quantize` of the same weights, for every registered FP format.
#[test]
fn gwqs2_snapshots_match_square_fake_quantize_bit_for_bit() {
    use gaussws::serve::WeightStore;
    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(2026);
    let mut covered = 0;
    for scheme in Registry::global().schemes() {
        let fmt = match (&scheme.codec, scheme.rounding, scheme.geometry) {
            (Codec::Fp(fmt), Rounding::NearestEven, Geometry::Square { .. }) => *fmt,
            _ => continue,
        };
        covered += 1;
        let block = scheme.block().unwrap();
        let store = WeightStore::from_params(&params, &cfg, scheme.clone(), 0).unwrap();
        let path = std::env::temp_dir()
            .join(format!("gaussws_quant_suite_{}.gwqs", scheme.label()));
        store.save(&path).unwrap();
        let served = WeightStore::load(&path).unwrap().to_params();
        for name in Params::linear_names(&cfg) {
            let m = params.get(&name);
            let w64: Vec<f64> = m.data.iter().map(|&x| x as f64).collect();
            let q = fake_quantize(
                &w64,
                m.rows,
                m.cols,
                Geometry::Square { block },
                &Codec::Fp(fmt),
                Rounding::NearestEven,
                0,
            );
            let got = served.get(&name);
            for (i, (&g, &want)) in got.data.iter().zip(q.data.iter()).enumerate() {
                assert_eq!(g, want as f32, "{}: {name}[{i}]", scheme.label());
            }
        }
    }
    // bf16, fp16, fp12_e4m7, fp8_{e4m3,e5m2,e3m4}, fp6_{e3m2,e2m3}, fp4_e2m1
    assert!(covered >= 9, "only {covered} FP RNE square schemes covered");
}

/// Stochastic rounding must be unbiased: over many independent draws the
/// mean quantized value converges to the input, for FP and INT codecs.
#[test]
fn stochastic_rounding_is_unbiased_in_expectation() {
    let cases = [
        (Codec::Fp(gaussws::numerics::formats::FP4_E2M1), 1.3f64),
        (Codec::Fp(gaussws::numerics::formats::FP8_E4M3), -0.777),
        (Codec::Int { bits: 8 }, 41.37),
        (Codec::Int { bits: 4 }, -2.6),
    ];
    let mut state = 0x1234_5678u32;
    for (codec, x) in cases {
        let mut acc = 0.0;
        let n = 40_000;
        for _ in 0..n {
            // xorshift32 as the random source
            state ^= state << 13;
            state ^= state >> 17;
            state ^= state << 5;
            acc += codec.quantize(x, Rounding::Stochastic, state);
        }
        let mean = acc / n as f64;
        // the quantization step around x bounds the standard error
        let step = match codec {
            Codec::Fp(f) => f.ulp(x),
            _ => 1.0,
        };
        let tol = 3.0 * step / (n as f64).sqrt() * 2.0 + 1e-12;
        assert!(
            (mean - x).abs() < tol.max(0.02 * step),
            "{codec:?}: mean {mean} vs {x} (step {step})"
        );
    }
}

/// Scheme-level stochastic quantization: averaging fake-quantized matrices
/// over many seeds converges to the original weights.
#[test]
fn stochastic_scheme_quantize_is_unbiased_elementwise() {
    let scheme = gaussws::quant::resolve("int8_sr").unwrap();
    let mut g = Gen::new(9);
    let (rows, cols) = (16, 16);
    let w = g.normal_vec(rows * cols);
    let trials = 400;
    let mut mean = vec![0f64; w.len()];
    for t in 0..trials {
        let q = scheme.quantize(&w, rows, cols, 1000 + t);
        for (m, v) in mean.iter_mut().zip(q.data.iter()) {
            *m += v / trials as f64;
        }
    }
    // per-element step is the block scale; mean error should be far below it
    let q0 = scheme.quantize(&w, rows, cols, 0);
    let max_scale = q0.scales.iter().cloned().fold(0.0f64, f64::max);
    for (i, (&m, &x)) in mean.iter().zip(w.iter()).enumerate() {
        assert!(
            (m - x).abs() < 0.25 * max_scale,
            "elem {i}: mean {m} vs {x} (scale {max_scale})"
        );
    }
}

/// `Scheme::quantize` must be exactly the explicit
/// (geometry × codec × rounding) `fake_quantize` call it names, on both
/// geometries — the one-engine guarantee the deleted mx shims used to pin.
#[test]
fn prop_scheme_quantize_matches_explicit_fake_quantize() {
    check("scheme == explicit fake_quantize", 15, |g: &mut Gen| {
        use gaussws::quant::Axis;
        let (rows, cols) = (g.usize_in(1, 50), g.usize_in(1, 50));
        let block = *g.choose(&[4usize, 16, 32]);
        let w = g.normal_vec(rows * cols);
        let fmt = gaussws::numerics::formats::FP6_E3M2;
        for geometry in
            [Geometry::Square { block }, Geometry::Vector { block, axis: Axis::Row }]
        {
            let direct = fake_quantize(
                &w,
                rows,
                cols,
                geometry,
                &Codec::Fp(fmt),
                Rounding::NearestEven,
                0,
            );
            let scheme = Scheme::new("t", Codec::Fp(fmt), Rounding::NearestEven, geometry)
                .quantize(&w, rows, cols, 0);
            if direct.data != scheme.data || direct.scales != scheme.scales {
                return Err(format!("{geometry:?} diverged"));
            }
        }
        Ok(())
    });
}

/// INT stores (including stochastic ones) survive the full
/// snapshot→save→load→serve hop byte-for-byte.
#[test]
fn int_and_sr_stores_roundtrip_through_gwqs2() {
    use gaussws::serve::WeightStore;
    let cfg = ModelConfig::tiny(Arch::Llama2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(31);
    for label in ["int8", "int4", "int8_sr", "fp4_e2m1_sr"] {
        let store = WeightStore::from_params(
            &params,
            &cfg,
            gaussws::quant::resolve(label).unwrap(),
            31,
        )
        .unwrap();
        let path = std::env::temp_dir().join(format!("gaussws_quant_suite_{label}.gwqs"));
        store.save(&path).unwrap();
        let back = WeightStore::load(&path).unwrap();
        assert_eq!(back.scheme, store.scheme, "{label}");
        assert_eq!(back.tensors, store.tensors, "{label}");
    }
}
