//! Unified-codec acceptance suite for the `quant` subsystem:
//!
//! * every registered packed scheme encode→decode round-trips *exactly*;
//! * GWQS2 snapshots written through `QuantScheme` dequantize bit-for-bit
//!   identical to a direct square-blockwise `fake_quantize` of the same
//!   weights for every registered FP format — the serving store inherits
//!   the Table C.1 fidelity claims through the one shared engine;
//! * stochastic rounding is unbiased in expectation (mean error → 0 over
//!   many draws) for both FP and INT codecs.

use gaussws::config::schema::{Arch, ModelConfig};
use gaussws::nn::transformer::{Params, Transformer};
use gaussws::numerics::Rounding;
use gaussws::quant::{fake_quantize, Codec, Geometry, QuantScheme, Registry, Scheme};
use gaussws::testing::prop::{check, Gen};

/// Every registered scheme with a packed codec must encode→decode exactly.
#[test]
fn every_registered_scheme_roundtrips_codes_exactly() {
    for scheme in Registry::global().schemes() {
        match scheme.codec {
            Codec::F32 => continue, // raw tensors, no codes
            Codec::Fp(fmt) => {
                for v in fmt.enumerate_non_negative() {
                    for signed in [v, -v] {
                        let code = scheme.encode(signed);
                        assert!(
                            (code as u32) < (1u32 << fmt.total_bits()),
                            "{}: code {code} wider than {} bits",
                            scheme.label(),
                            fmt.total_bits()
                        );
                        assert_eq!(
                            scheme.decode(code),
                            signed,
                            "{}: {signed} -> {code}",
                            scheme.label()
                        );
                    }
                }
            }
            Codec::Int { bits } => {
                let m = (1i64 << (bits - 1)) - 1;
                for v in -m..=m {
                    let code = scheme.encode(v as f64);
                    assert_eq!(scheme.decode(code), v as f64, "{}: {v}", scheme.label());
                }
            }
        }
    }
}

/// Random fake-quantized values must survive the pack→unpack codec hop at
/// the block scale, for every registered square-blockwise scheme.
#[test]
fn prop_quantized_values_roundtrip_through_codes() {
    check("scheme codes roundtrip at scale", 20, |g: &mut Gen| {
        for scheme in Registry::global().schemes() {
            if !scheme.codec.is_packed() || !matches!(scheme.geometry, Geometry::Square { .. }) {
                continue;
            }
            let (rows, cols) = (g.usize_in(1, 40), g.usize_in(1, 40));
            let w = g.normal_vec(rows * cols);
            let q = scheme.quantize(&w, rows, cols, g.u64());
            let block = scheme.block().unwrap();
            let grid_c = cols.div_ceil(block);
            for (i, &v) in q.data.iter().enumerate() {
                let (r, c) = (i / cols, i % cols);
                let s = q.scales[(r / block) * grid_c + c / block];
                let back = scheme.decode(scheme.encode(v / s)) * s;
                if back != v {
                    return Err(format!("{}: elem {i}: {v} -> {back}", scheme.label()));
                }
            }
        }
        Ok(())
    });
}

/// The acceptance criterion: a GWQS2 snapshot written via `QuantScheme`
/// must dequantize bit-for-bit identical to a direct square-blockwise RNE
/// `fake_quantize` of the same weights, for every registered FP format.
#[test]
fn gwqs2_snapshots_match_square_fake_quantize_bit_for_bit() {
    use gaussws::serve::WeightStore;
    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(2026);
    let mut covered = 0;
    for scheme in Registry::global().schemes() {
        let fmt = match (&scheme.codec, scheme.rounding, scheme.geometry) {
            (Codec::Fp(fmt), Rounding::NearestEven, Geometry::Square { .. }) => *fmt,
            _ => continue,
        };
        covered += 1;
        let block = scheme.block().unwrap();
        let store = WeightStore::from_params(&params, &cfg, scheme.clone(), 0).unwrap();
        let path = std::env::temp_dir()
            .join(format!("gaussws_quant_suite_{}.gwqs", scheme.label()));
        store.save(&path).unwrap();
        let served = WeightStore::load(&path).unwrap().to_params();
        for name in Params::linear_names(&cfg) {
            let m = params.get(&name);
            let w64: Vec<f64> = m.data.iter().map(|&x| x as f64).collect();
            let q = fake_quantize(
                &w64,
                m.rows,
                m.cols,
                Geometry::Square { block },
                &Codec::Fp(fmt),
                Rounding::NearestEven,
                0,
            );
            let got = served.get(&name);
            for (i, (&g, &want)) in got.data.iter().zip(q.data.iter()).enumerate() {
                assert_eq!(g, want as f32, "{}: {name}[{i}]", scheme.label());
            }
        }
    }
    // bf16, fp16, fp12_e4m7, fp8_{e4m3,e5m2,e3m4}, fp6_{e3m2,e2m3}, fp4_e2m1
    assert!(covered >= 9, "only {covered} FP RNE square schemes covered");
}

/// Stochastic rounding must be unbiased: over many independent draws the
/// mean quantized value converges to the input, for FP and INT codecs.
#[test]
fn stochastic_rounding_is_unbiased_in_expectation() {
    let cases = [
        (Codec::Fp(gaussws::numerics::formats::FP4_E2M1), 1.3f64),
        (Codec::Fp(gaussws::numerics::formats::FP8_E4M3), -0.777),
        (Codec::Int { bits: 8 }, 41.37),
        (Codec::Int { bits: 4 }, -2.6),
    ];
    let mut state = 0x1234_5678u32;
    for (codec, x) in cases {
        let mut acc = 0.0;
        let n = 40_000;
        for _ in 0..n {
            // xorshift32 as the random source
            state ^= state << 13;
            state ^= state >> 17;
            state ^= state << 5;
            acc += codec.quantize(x, Rounding::Stochastic, state);
        }
        let mean = acc / n as f64;
        // the quantization step around x bounds the standard error
        let step = match codec {
            Codec::Fp(f) => f.ulp(x),
            _ => 1.0,
        };
        let tol = 3.0 * step / (n as f64).sqrt() * 2.0 + 1e-12;
        assert!(
            (mean - x).abs() < tol.max(0.02 * step),
            "{codec:?}: mean {mean} vs {x} (step {step})"
        );
    }
}

/// Scheme-level stochastic quantization: averaging fake-quantized matrices
/// over many seeds converges to the original weights.
#[test]
fn stochastic_scheme_quantize_is_unbiased_elementwise() {
    let scheme = gaussws::quant::resolve("int8_sr").unwrap();
    let mut g = Gen::new(9);
    let (rows, cols) = (16, 16);
    let w = g.normal_vec(rows * cols);
    let trials = 400;
    let mut mean = vec![0f64; w.len()];
    for t in 0..trials {
        let q = scheme.quantize(&w, rows, cols, 1000 + t);
        for (m, v) in mean.iter_mut().zip(q.data.iter()) {
            *m += v / trials as f64;
        }
    }
    // per-element step is the block scale; mean error should be far below it
    let q0 = scheme.quantize(&w, rows, cols, 0);
    let max_scale = q0.scales.iter().cloned().fold(0.0f64, f64::max);
    for (i, (&m, &x)) in mean.iter().zip(w.iter()).enumerate() {
        assert!(
            (m - x).abs() < 0.25 * max_scale,
            "elem {i}: mean {m} vs {x} (scale {max_scale})"
        );
    }
}

/// `Scheme::quantize` must be exactly the explicit
/// (geometry × codec × rounding) `fake_quantize` call it names, on both
/// geometries — the one-engine guarantee the deleted mx shims used to pin.
#[test]
fn prop_scheme_quantize_matches_explicit_fake_quantize() {
    check("scheme == explicit fake_quantize", 15, |g: &mut Gen| {
        use gaussws::quant::Axis;
        let (rows, cols) = (g.usize_in(1, 50), g.usize_in(1, 50));
        let block = *g.choose(&[4usize, 16, 32]);
        let w = g.normal_vec(rows * cols);
        let fmt = gaussws::numerics::formats::FP6_E3M2;
        for geometry in
            [Geometry::Square { block }, Geometry::Vector { block, axis: Axis::Row }]
        {
            let direct = fake_quantize(
                &w,
                rows,
                cols,
                geometry,
                &Codec::Fp(fmt),
                Rounding::NearestEven,
                0,
            );
            let scheme = Scheme::new("t", Codec::Fp(fmt), Rounding::NearestEven, geometry)
                .quantize(&w, rows, cols, 0);
            if direct.data != scheme.data || direct.scales != scheme.scales {
                return Err(format!("{geometry:?} diverged"));
            }
        }
        Ok(())
    });
}

/// Subnormal inputs: values inside every FP format's subnormal range must
/// quantize to exactly representable values that survive the code hop, and
/// a block whose amax is subnormal gets a scale that keeps it resolvable.
#[test]
fn prop_subnormal_inputs_roundtrip_per_fp_format() {
    check("subnormal edge cases per format", 15, |g: &mut Gen| {
        for scheme in Registry::global().schemes() {
            let Codec::Fp(fmt) = scheme.codec else { continue };
            if scheme.rounding != Rounding::NearestEven {
                continue; // deterministic check
            }
            // a point strictly inside the subnormal range
            let x = fmt.min_subnormal() * g.f64_in(0.6, (1u64 << fmt.man_bits) as f64 - 0.4);
            let q = scheme.codec.quantize(x, Rounding::NearestEven, 0);
            if !fmt.is_representable(q) {
                return Err(format!("{}: subnormal {x} -> unrepresentable {q}", scheme.label()));
            }
            if q != 0.0 {
                let back = scheme.decode(scheme.encode(q));
                if back != q {
                    return Err(format!("{}: subnormal code hop {q} -> {back}", scheme.label()));
                }
            }
            // below half the min subnormal RNE underflows to (signed) zero
            let tiny = fmt.min_subnormal() * 0.49;
            if scheme.codec.quantize(tiny, Rounding::NearestEven, 0) != 0.0 {
                return Err(format!("{}: {tiny} failed to underflow", scheme.label()));
            }
        }
        Ok(())
    });
}

/// Overflow inputs: magnitudes beyond max_finite saturate (or go to inf
/// for formats with inf codes), and the result still encodes/decodes
/// exactly. Blockwise quantize never clips — the po2 scale maps the block
/// amax inside range.
#[test]
fn prop_overflow_inputs_per_fp_format() {
    check("overflow edge cases per format", 15, |g: &mut Gen| {
        for scheme in Registry::global().schemes() {
            let Codec::Fp(fmt) = scheme.codec else { continue };
            let huge = fmt.max_finite() * g.f64_in(1.5, 1e6);
            for signed in [huge, -huge] {
                let q = scheme.codec.quantize(signed, Rounding::NearestEven, 0);
                let expect_inf = fmt.has_inf_nan;
                if expect_inf && !q.is_infinite() {
                    return Err(format!("{}: {signed} should overflow to inf, got {q}", scheme.label()));
                }
                if !expect_inf && q.abs() != fmt.max_finite() {
                    return Err(format!("{}: {signed} should saturate, got {q}", scheme.label()));
                }
                if q.signum() != signed.signum() {
                    return Err(format!("{}: overflow lost the sign of {signed}", scheme.label()));
                }
                if expect_inf {
                    let back = scheme.decode(scheme.encode(q));
                    if back != q {
                        return Err(format!("{}: inf code hop {q} -> {back}", scheme.label()));
                    }
                }
            }
            // blockwise: the shared scale absorbs the magnitude — no clip
            let w = [huge, huge / 2.0, 0.0, -huge];
            let q = fake_quantize(
                &w,
                2,
                2,
                Geometry::Square { block: 2 },
                &scheme.codec,
                Rounding::NearestEven,
                0,
            );
            if q.data.iter().any(|v| !v.is_finite()) {
                return Err(format!("{}: blockwise quantize clipped to non-finite", scheme.label()));
            }
        }
        Ok(())
    });
}

/// All-zero blocks: unit scale, zero outputs, zero codes — and the shared
/// scale of a mixed block is never poisoned by its zero elements.
#[test]
fn all_zero_blocks_quantize_to_zero_with_unit_scale() {
    for scheme in Registry::global().schemes() {
        if !scheme.codec.is_packed() {
            continue;
        }
        let w = [0.0f64; 16];
        let q = scheme.quantize(&w, 4, 4, 7);
        assert!(q.scales.iter().all(|&s| s == 1.0), "{}: {:?}", scheme.label(), q.scales);
        assert!(q.data.iter().all(|&v| v == 0.0), "{}", scheme.label());
        let code = scheme.encode(0.0);
        assert_eq!(scheme.decode(code), 0.0, "{}", scheme.label());
    }
}

/// The NaN policy (documented on `Codec::quantize` / `FpFormat::cast_mode`):
/// a NaN element never contaminates the shared block scale or its
/// neighbours; per element, inf/nan formats propagate NaN, saturating FP
/// formats clamp it to ±max_finite, and INT codecs map it to 0.
#[test]
fn nan_policy_is_enforced() {
    use gaussws::numerics::formats;
    // elementwise policy per codec family
    let ieee = Codec::Fp(formats::BF16);
    assert!(ieee.quantize(f64::NAN, Rounding::NearestEven, 0).is_nan(), "ieee formats propagate");
    let sat = Codec::Fp(formats::FP8_E3M4);
    let q = sat.quantize(f64::NAN, Rounding::NearestEven, 0);
    assert_eq!(q.abs(), formats::FP8_E3M4.max_finite(), "saturating formats clamp NaN: {q}");
    let int = Codec::Int { bits: 8 };
    assert_eq!(int.quantize(f64::NAN, Rounding::NearestEven, 0), 0.0, "INT maps NaN to 0");
    // ieee formats can round-trip NaN through the packed code
    for fmt in [formats::BF16, formats::FP16, formats::FP8_E5M2] {
        let codec = Codec::Fp(fmt);
        assert!(codec.decode(codec.encode(f64::NAN)).is_nan(), "{fmt:?}: NaN code hop");
    }
    // a single NaN inside a block: neighbours and the shared scale match
    // the same block with the NaN replaced by zero (amax folds skip NaN)
    let scheme = gaussws::quant::resolve("fp8_e3m4").unwrap();
    let mut w: Vec<f64> = (0..64).map(|i| (i as f64 - 30.0) * 0.17).collect();
    let mut clean = w.clone();
    w[13] = f64::NAN;
    clean[13] = 0.0;
    let qn = scheme.quantize(&w, 8, 8, 0);
    let qc = scheme.quantize(&clean, 8, 8, 0);
    assert_eq!(qn.scales, qc.scales, "NaN poisoned a shared scale");
    for (i, (a, b)) in qn.data.iter().zip(qc.data.iter()).enumerate() {
        if i == 13 {
            // NaN saturates at the block's scale: ±max_finite × scale
            assert_eq!(a.abs(), formats::FP8_E3M4.max_finite() * qn.scales[0], "elem 13: {a}");
        } else {
            assert_eq!(a, b, "elem {i}: neighbour of NaN changed");
        }
    }
}

/// SR determinism under `tensor_seed`: the documented contract is that the
/// same (name, salt) makes two *independent* stochastic quantize calls
/// byte-identical — this is what keeps SR snapshots reproducible across
/// the quantize/serve/eval paths — while a different name or salt diverges.
#[test]
fn sr_determinism_under_tensor_seed_across_independent_calls() {
    use gaussws::quant::tensor_seed;
    let scheme = gaussws::quant::resolve("int8_sr").unwrap();
    let mut g = Gen::new(41);
    let w = g.normal_vec(24 * 24);
    let a = scheme.quantize(&w, 24, 24, tensor_seed("blk0.up", 2026));
    let b = scheme.quantize(&w, 24, 24, tensor_seed("blk0.up", 2026));
    assert_eq!(a.data, b.data, "same tensor name + salt must reproduce exactly");
    assert_eq!(a.scales, b.scales);
    let other_name = scheme.quantize(&w, 24, 24, tensor_seed("blk1.up", 2026));
    let other_salt = scheme.quantize(&w, 24, 24, tensor_seed("blk0.up", 2027));
    assert_ne!(a.data, other_name.data, "different tensor names must decorrelate");
    assert_ne!(a.data, other_salt.data, "different salts must decorrelate");
}

/// INT stores (including stochastic ones) survive the full
/// snapshot→save→load→serve hop byte-for-byte.
#[test]
fn int_and_sr_stores_roundtrip_through_gwqs2() {
    use gaussws::serve::WeightStore;
    let cfg = ModelConfig::tiny(Arch::Llama2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(31);
    for label in ["int8", "int4", "int8_sr", "fp4_e2m1_sr"] {
        let store = WeightStore::from_params(
            &params,
            &cfg,
            gaussws::quant::resolve(label).unwrap(),
            31,
        )
        .unwrap();
        let path = std::env::temp_dir().join(format!("gaussws_quant_suite_{label}.gwqs"));
        store.save(&path).unwrap();
        let back = WeightStore::load(&path).unwrap();
        assert_eq!(back.scheme, store.scheme, "{label}");
        assert_eq!(back.tensors, store.tensors, "{label}");
    }
}
