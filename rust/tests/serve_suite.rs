//! Serve-layer integration + MX round-trip property tests: quantization
//! error bounds per element type, quantized-snapshot fidelity against the
//! `fq_inference` quantization path, KV-cache decode parity, end-to-end
//! continuous-batching behaviour, and the Table C.1 degradation pattern of
//! the FP weight-store modes. Pure rust — no artifacts or PJRT needed.

use gaussws::config::schema::{Arch, ModelConfig};
use gaussws::data::{SynthCorpus, SynthSpec};
use gaussws::nn::transformer::{DecodeCache, Params, Transformer};
use gaussws::numerics::fpformat::formats;
use gaussws::numerics::Rounding;
use gaussws::quant::{fake_quantize, resolve, Codec, Geometry, Quantized};
use gaussws::serve::{Engine, EngineConfig, GenRequest, WeightStore};
use gaussws::testing::prop::{check, Gen};

/// Square-blockwise RNE fake quantization through the quant engine (what
/// the deleted `mx::quantize_square` shim used to wrap).
fn fq_square(
    w: &[f64],
    rows: usize,
    cols: usize,
    block: usize,
    fmt: gaussws::numerics::FpFormat,
) -> Quantized {
    fake_quantize(
        w,
        rows,
        cols,
        Geometry::Square { block },
        &Codec::Fp(fmt),
        Rounding::NearestEven,
        0,
    )
}

// ---------------------------------------------------------------- MX bounds

/// Round-trip error bound of square-blockwise fake quantization for an FP
/// element type: RNE casting gives relative error ≤ 2^-(m+1) in the normal
/// range, and absolute error ≤ scale · min_subnormal / 2 below it. The po2
/// scale maps each block's max into range, so nothing clips.
fn assert_roundtrip_bounds(g: &mut Gen, fmt: gaussws::numerics::FpFormat) -> Result<(), String> {
    let rows = g.usize_in(1, 70);
    let cols = g.usize_in(1, 70);
    let block = *g.choose(&[4usize, 16, 32]);
    let w = g.normal_vec(rows * cols);
    let q = fq_square(&w, rows, cols, block, fmt);
    let grid_c = cols.div_ceil(block);
    let rel = 0.5 * (-(fmt.man_bits as f64)).exp2();
    for (i, (&orig, &quant)) in w.iter().zip(q.data.iter()).enumerate() {
        let (r, c) = (i / cols, i % cols);
        let s = q.scales[(r / block) * grid_c + c / block];
        let bound = (rel * orig.abs()).max(0.5 * s * fmt.min_subnormal()) * (1.0 + 1e-12) + 1e-300;
        if (orig - quant).abs() > bound {
            return Err(format!(
                "({rows}x{cols} b{block}) elem {i}: |{orig} - {quant}| > {bound} (scale {s})"
            ));
        }
    }
    Ok(())
}

#[test]
fn prop_fp8_e3m4_roundtrip_bounded() {
    check("fp8_e3m4 square roundtrip", 30, |g| assert_roundtrip_bounds(g, formats::FP8_E3M4));
}

#[test]
fn prop_fp6_e3m2_roundtrip_bounded() {
    check("fp6_e3m2 square roundtrip", 30, |g| assert_roundtrip_bounds(g, formats::FP6_E3M2));
}

#[test]
fn prop_bf16_roundtrip_bounded() {
    check("bf16 square roundtrip", 20, |g| assert_roundtrip_bounds(g, formats::BF16));
}

#[test]
fn prop_bf16_exact_for_representable_values() {
    // a block of already-bf16 values within the po2-scaled range must
    // survive BF16 square-blockwise quantization untouched
    check("bf16 exact on bf16 inputs", 30, |g| {
        let n = 32usize;
        let w: Vec<f64> = (0..n * n).map(|_| formats::BF16.cast(g.normal())).collect();
        let q = fq_square(&w, n, n, 32, formats::BF16);
        for (i, (&a, &b)) in w.iter().zip(q.data.iter()).enumerate() {
            if a != b {
                return Err(format!("elem {i}: {a} != {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantization_error_decreases_with_precision() {
    // Table C.1 shape: rms quantization error must grow as mantissas shrink
    check("precision ladder", 15, |g| {
        let n = 64usize;
        let w = g.normal_vec(n * n);
        let rms = |fmt| {
            let q = fq_square(&w, n, n, 32, fmt);
            (w.iter().zip(q.data.iter()).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
                / w.len() as f64)
                .sqrt()
        };
        let (e_bf16, e_fp8, e_fp6) =
            (rms(formats::BF16), rms(formats::FP8_E3M4), rms(formats::FP6_E3M2));
        if e_bf16 <= e_fp8 && e_fp8 <= e_fp6 {
            Ok(())
        } else {
            Err(format!("not monotone: bf16 {e_bf16} fp8 {e_fp8} fp6 {e_fp6}"))
        }
    });
}

// ------------------------------------------------- snapshot fidelity

fn tiny_model(arch: Arch, seed: u64) -> (ModelConfig, Transformer, Params) {
    let cfg = ModelConfig::tiny(arch);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(seed);
    (cfg, model, params)
}

/// The fq_inference-style quantization path: cast every linear in place.
fn quantize_linears(params: &Params, cfg: &ModelConfig, fmt: gaussws::numerics::FpFormat) -> Params {
    let mut out = params.clone();
    for name in Params::linear_names(cfg) {
        let m = out.get_mut(&name);
        let w64: Vec<f64> = m.data.iter().map(|&x| x as f64).collect();
        let q = fq_square(&w64, m.rows, m.cols, 32, fmt);
        for (dst, &src) in m.data.iter_mut().zip(q.data.iter()) {
            *dst = src as f32;
        }
    }
    out
}

#[test]
fn snapshot_reproduces_fq_inference_logits() {
    // the weight store's pack→unpack must land on the same weights as the
    // direct square fake-quantize path, hence identical logits
    for arch in [Arch::Gpt2, Arch::Llama2] {
        let (cfg, model, params) = tiny_model(arch, 21);
        for fmt in [formats::BF16, formats::FP8_E3M4, formats::FP6_E3M2] {
            let direct = quantize_linears(&params, &cfg, fmt);
            let scheme = gaussws::quant::Scheme::new(
                "test",
                gaussws::quant::Codec::Fp(fmt),
                gaussws::numerics::Rounding::NearestEven,
                gaussws::quant::Geometry::Square { block: 32 },
            );
            let store = WeightStore::from_params(&params, &cfg, scheme, 21).unwrap();
            let served = store.to_params();
            let toks = [1usize, 9, 33, 7, 12];
            let a = model.forward(&direct, &toks);
            let b = model.forward(&served, &toks);
            assert_eq!(a.data, b.data, "{arch:?}/{fmt:?}: logits diverge");
        }
    }
}

#[test]
fn snapshot_eval_loss_follows_table_c1_degradation() {
    // deployment check: FP stores keep the eval loss finite, BF16 tracks
    // master f32 tightly, and lower-precision stores degrade gracefully
    let (cfg, model, params) = tiny_model(Arch::Gpt2, 33);
    let corpus = SynthCorpus::generate(SynthSpec {
        vocab: cfg.vocab,
        len: 1 << 15,
        seed: 99,
        ..Default::default()
    });
    let eval = |p: &Params| -> f64 {
        let mut total = 0.0;
        let n = 4;
        for k in 0..n {
            let start = 300 + k * 1200;
            let toks: Vec<usize> =
                corpus.tokens[start..start + 49].iter().map(|&t| t as usize).collect();
            total += model.loss(p, &toks);
        }
        total / n as f64
    };
    let base = eval(&params);
    assert!(base.is_finite());
    let loss_of = |mode: &str| {
        let store =
            WeightStore::from_params(&params, &cfg, resolve(mode).unwrap(), 33).unwrap();
        eval(&store.to_params())
    };
    let (l_bf16, l_fp8, l_fp6) = (loss_of("bf16"), loss_of("fp8_e3m4"), loss_of("fp6_e3m2"));
    assert!(l_bf16.is_finite() && l_fp8.is_finite() && l_fp6.is_finite());
    // bf16 is indistinguishable from master at model scale
    assert!((l_bf16 - base).abs() < 0.02, "bf16 {l_bf16} vs f32 {base}");
    // graceful degradation: fp8/fp6 stay within a loose band of master
    assert!(l_fp8 < base + 0.5, "fp8 {l_fp8} vs {base}");
    assert!(l_fp6 < base + 2.0, "fp6 {l_fp6} vs {base}");
}

#[test]
fn snapshot_file_roundtrip_serves_identically() {
    let (cfg, model, params) = tiny_model(Arch::Gpt2, 44);
    let store =
        WeightStore::from_params(&params, &cfg, resolve("fp8_e3m4").unwrap(), 44).unwrap();
    let path = std::env::temp_dir().join("gaussws_serve_suite.gwqs");
    store.save(&path).unwrap();
    let loaded = WeightStore::load(&path).unwrap();
    let toks = [5usize, 6, 7, 8];
    let a = model.forward(&store.to_params(), &toks);
    let b = model.forward(&loaded.to_params(), &toks);
    assert_eq!(a.data, b.data);
}

// ----------------------------------------------- decode + engine end-to-end

#[test]
fn kv_decode_matches_forward_on_quantized_weights() {
    // decode parity must hold on the served (quantized) weights too
    let (cfg, model, params) = tiny_model(Arch::Llama2, 55);
    let store =
        WeightStore::from_params(&params, &cfg, resolve("fp8_e4m3").unwrap(), 55).unwrap();
    let served = store.to_params();
    let toks = [2usize, 40, 11, 3, 25];
    let full = model.forward(&served, &toks);
    let mut cache = DecodeCache::new(&cfg, toks.len());
    for (i, &t) in toks.iter().enumerate() {
        let logits = model.decode_step(&served, t, &mut cache);
        for (c, &got) in logits.iter().enumerate() {
            let want = full.at(i, c);
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "pos {i} col {c}: {got} vs {want}"
            );
        }
    }
}

#[test]
fn engine_batches_and_serves_all_store_modes() {
    let (cfg, _model, params) = tiny_model(Arch::Gpt2, 66);
    for mode in ["f32", "bf16", "fp8_e3m4", "fp6_e3m2"] {
        let store =
            WeightStore::from_params(&params, &cfg, resolve(mode).unwrap(), 66).unwrap();
        let mut engine = Engine::from_store(
            &store,
            EngineConfig {
                max_batch: 4,
                kv_block: 8,
                prefix_cache: false,
                threads: 2,
                ..EngineConfig::default()
            },
        );
        for id in 0..6u64 {
            engine
                .enqueue(GenRequest::greedy(id, vec![1 + id as usize * 3, 8, 2], 5))
                .unwrap();
        }
        let done = engine.run_to_completion();
        assert_eq!(done.len(), 6, "{mode}");
        assert!(done.iter().all(|r| r.tokens.len() == 5), "{mode}");
        assert!(engine.stats.max_occupancy() > 1, "{mode}: no batching observed");
        assert!(engine.stats.tokens_per_sec() >= 0.0);
        let (live, _, high_water, _) = engine.kv_usage();
        assert_eq!(live, 0, "{mode}: blocks leaked");
        assert!(high_water >= 4, "{mode}: arena never filled (high water {high_water})");
    }
}

#[test]
fn queue_drains_when_requests_exceed_blocks() {
    // more demand than KV blocks: admission must throttle on the block
    // budget, retirement must recycle blocks, and every request must still
    // complete. 2 blocks of 8 positions; each request needs 1 block.
    let (cfg, _model, params) = tiny_model(Arch::Gpt2, 77);
    let store = WeightStore::from_params(&params, &cfg, resolve("bf16").unwrap(), 77).unwrap();
    let mut engine = Engine::from_store(
        &store,
        EngineConfig {
            max_batch: 8,
            kv_block: 8,
            kv_blocks: 2,
            prefix_cache: false,
            threads: 1,
            ..EngineConfig::default()
        },
    );
    for id in 0..7u64 {
        engine.enqueue(GenRequest::greedy(id, vec![4, 5], 3 + (id as usize % 3))).unwrap();
    }
    let done = engine.run_to_completion();
    assert_eq!(done.len(), 7);
    let (_, blocks, high_water, _) = engine.kv_usage();
    assert_eq!(blocks, 2);
    assert_eq!(high_water, 2);
    assert!(engine.stats.max_occupancy() <= 2, "at most 2 one-block sequences fit");
}
