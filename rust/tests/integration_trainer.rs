//! End-to-end trainer integration over the real artifacts: loss decreases,
//! runs are deterministic per seed, checkpoints resume exactly, PQT
//! bitwidths anneal, and data-parallel workers agree with single-worker
//! training on expectations. Requires `make artifacts` (skips otherwise).

use gaussws::config::schema::{Optimizer, TrainConfig};
use gaussws::coordinator::Trainer;
use gaussws::runtime::Runtime;

fn trainer(artifact: &str, steps: usize, seed: u64, workers: usize, opt: Optimizer) -> Option<Trainer> {
    let runtime = match Runtime::new("artifacts") {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping (artifacts not built): {e}");
            return None;
        }
    };
    let cfg = TrainConfig {
        steps,
        warmup_steps: 3,
        max_lr: 1e-3,
        min_lr: 1e-4,
        optimizer: opt,
        workers,
        seed,
        ..Default::default()
    };
    Some(Trainer::new(runtime, artifact, cfg, "itest").unwrap())
}

#[test]
fn loss_decreases_on_tiny_gpt2_gaussws() {
    let Some(mut t) = trainer("tiny_gpt2.gaussws_all", 25, 1, 1, Optimizer::AdamW) else {
        return;
    };
    t.run(25, 0).unwrap();
    let losses = t.log.losses();
    let first = losses[0];
    let last_avg: f64 = losses[20..].iter().sum::<f64>() / 5.0;
    assert!(
        last_avg < first - 0.15,
        "loss should drop: first={first:.3} last5={last_avg:.3}"
    );
    // init loss ~ ln(vocab) = ln 256 ~ 5.55
    assert!((first - 5.55).abs() < 0.8, "init loss {first}");
}

#[test]
fn deterministic_per_seed() {
    let Some(mut a) = trainer("tiny_gpt2.gaussws_all", 6, 9, 1, Optimizer::AdamW) else {
        return;
    };
    let Some(mut b) = trainer("tiny_gpt2.gaussws_all", 6, 9, 1, Optimizer::AdamW) else {
        return;
    };
    a.run(6, 0).unwrap();
    b.run(6, 0).unwrap();
    assert_eq!(a.log.losses(), b.log.losses());
    let Some(mut c) = trainer("tiny_gpt2.gaussws_all", 6, 10, 1, Optimizer::AdamW) else {
        return;
    };
    c.run(6, 0).unwrap();
    assert_ne!(a.log.losses(), c.log.losses());
}

#[test]
fn checkpoint_resume_is_exact() {
    let Some(mut full) = trainer("tiny_gpt2.gaussws_all", 10, 4, 1, Optimizer::AdamW) else {
        return;
    };
    full.run(5, 0).unwrap();
    let ck = std::env::temp_dir().join("gaussws_itest.ck");
    full.save_checkpoint(ck.to_str().unwrap()).unwrap();
    full.run(5, 0).unwrap();

    let Some(mut resumed) = trainer("tiny_gpt2.gaussws_all", 10, 4, 1, Optimizer::AdamW) else {
        return;
    };
    resumed.load_checkpoint(ck.to_str().unwrap()).unwrap();
    assert_eq!(resumed.step, 5);
    resumed.run(5, 0).unwrap();
    // NOTE: optimizer moments are not in the checkpoint, so trajectories
    // only match approximately; params at resume point match exactly.
    let l_full = full.log.losses()[5];
    let l_res = resumed.log.losses()[0];
    assert!(
        (l_full - l_res).abs() < 0.2,
        "resume loss {l_res} vs original {l_full}"
    );
}

#[test]
fn bitwidths_anneal_toward_target() {
    let Some(mut t) = trainer("tiny_gpt2.gaussws_all", 30, 2, 1, Optimizer::AdamW) else {
        return;
    };
    // the paper anneals over 600k steps with wd=0.1; at 30 test steps we
    // scale the decay up so the mechanism is observable
    t.bi_weight_decay = 20.0;
    let bt0: f32 = t.bt_of(&t.bi_layer_names()[0]).unwrap()[0];
    assert_eq!(bt0, 6.0); // b_init
    t.run(30, 0).unwrap();
    for name in t.bi_layer_names() {
        let bt = t.bt_of(&name).unwrap();
        let mean: f32 = bt.iter().sum::<f32>() / bt.len() as f32;
        assert!(mean < 6.0, "{name}: b_t should decay below b_init, got {mean}");
        assert!(mean > 3.5, "{name}: b_t should stay near/above b_target, got {mean}");
    }
}

#[test]
fn multi_worker_matches_bigger_batch_direction() {
    // 2 workers see 2x tokens/step; loss after N steps should be <= the
    // 1-worker run within tolerance (more data, same steps).
    let Some(mut w1) = trainer("tiny_gpt2.bf16", 12, 5, 1, Optimizer::AdamW) else {
        return;
    };
    let Some(mut w2) = trainer("tiny_gpt2.bf16", 12, 5, 2, Optimizer::AdamW) else {
        return;
    };
    w1.run(12, 0).unwrap();
    w2.run(12, 0).unwrap();
    assert_eq!(w2.tokens_per_step(), 2 * w1.tokens_per_step());
    let f1 = w1.log.final_loss().unwrap();
    let f2 = w2.log.final_loss().unwrap();
    assert!(f2 < f1 + 0.15, "2-worker {f2} vs 1-worker {f1}");
}

#[test]
fn adam_mini_trains_too() {
    let Some(mut t) = trainer("tiny_gpt2.gaussws_all", 15, 6, 1, Optimizer::AdamMini) else {
        return;
    };
    t.run(15, 0).unwrap();
    let losses = t.log.losses();
    assert!(losses[14] < losses[0], "{:?}", (losses[0], losses[14]));
    // Adam-mini optimizer state is smaller than AdamW's would be
    // (~4B/param vs 8B/param); check through the memory model
    let mem = t.memory_model_bytes("gaussws");
    let n: usize = t.params.values().map(|v| v.len()).sum();
    assert!(mem < n * 11, "mem {mem} vs params {n}");
}

#[test]
fn eval_artifact_runs() {
    let Some(mut t) = trainer("tiny_gpt2.gaussws_all", 5, 7, 1, Optimizer::AdamW) else {
        return;
    };
    t.run(5, 0).unwrap();
    let loss = t.evaluate("tiny_gpt2.gaussws_all", 2).unwrap();
    assert!(loss.is_finite() && loss > 0.0 && loss < 10.0, "{loss}");
}

#[test]
fn diffq_and_baseline_artifacts_train() {
    for tag in ["tiny_gpt2.diffq_all", "tiny_gpt2.bf16"] {
        let Some(mut t) = trainer(tag, 8, 8, 1, Optimizer::AdamW) else { return };
        t.run(8, 0).unwrap();
        assert!(t.log.losses().iter().all(|l| l.is_finite()), "{tag}");
    }
}
