//! Paged-KV equivalence suite — the correctness contract of the paged
//! serving memory architecture:
//!
//! * `PagedKv` decode is **bit-identical** to the contiguous `DecodeCache`
//!   across random prompt lengths and block sizes;
//! * chunked prefill is bit-identical to token-by-token prefill for any
//!   chunk split, on both storage layouts;
//! * prefix-shared sequences diverge correctly after copy-on-write (engine
//!   outputs with the prefix cache on equal those with it off);
//! * preempt → re-prefill yields the same greedy completion as an
//!   unpreempted run, and the arena never leaks blocks.

use gaussws::config::schema::{Arch, ModelConfig};
use gaussws::nn::kv::{KvStorage, PagedKv};
use gaussws::nn::transformer::{DecodeCache, Params, Transformer};
use gaussws::serve::{Engine, EngineConfig, GenRequest};
use gaussws::testing::prop::{check, Gen};

fn tiny(arch: Arch, seed: u64) -> (Transformer, Params) {
    let cfg = ModelConfig::tiny(arch);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(seed);
    (model, params)
}

fn prompt_of(g: &mut Gen, len: usize, vocab: usize) -> Vec<usize> {
    (0..len).map(|_| g.usize_in(0, vocab - 1)).collect()
}

#[test]
fn prop_paged_decode_bit_identical_to_contiguous() {
    check("paged == contiguous decode", 12, |g| {
        let arch = *g.choose(&[Arch::Gpt2, Arch::Llama2]);
        let (model, params) = tiny(arch, 7);
        let vocab = model.cfg.vocab;
        let len = g.usize_in(1, 24);
        let block = *g.choose(&[1usize, 2, 3, 8, 16, 64]);
        let tokens = prompt_of(g, len, vocab);
        let mut contiguous = DecodeCache::new(&model.cfg, len);
        let mut paged = PagedKv::new(&model.cfg, block, len);
        for &tok in &tokens {
            let a = model.decode_step(&params, tok, &mut contiguous);
            let b = model.decode_step(&params, tok, &mut paged);
            if a != b {
                return Err(format!("{arch:?} len {len} block {block}: logits diverge"));
            }
        }
        if paged.len() != contiguous.len() {
            return Err("cursor mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_chunked_prefill_bit_identical_for_any_split() {
    check("chunked == token-by-token prefill", 12, |g| {
        let arch = *g.choose(&[Arch::Gpt2, Arch::Llama2]);
        let (model, params) = tiny(arch, 8);
        let vocab = model.cfg.vocab;
        let len = g.usize_in(2, 24);
        let block = *g.choose(&[2usize, 4, 16]);
        let tokens = prompt_of(g, len, vocab);
        // reference: token-by-token on the contiguous cache
        let mut reference = DecodeCache::new(&model.cfg, len);
        let mut want = Vec::new();
        for &tok in &tokens {
            want = model.decode_step(&params, tok, &mut reference);
        }
        // random chunk split on a paged cache
        let mut paged = PagedKv::new(&model.cfg, block, len);
        let mut got = Vec::new();
        let mut fed = 0;
        while fed < len {
            let chunk = g.usize_in(1, len - fed);
            got = model.prefill_chunk(&params, &tokens[fed..fed + chunk], &mut paged);
            fed += chunk;
        }
        if got != want {
            return Err(format!("{arch:?} len {len} block {block}: chunked logits diverge"));
        }
        // the cache contents agree too: one more identical token must give
        // identical logits from both caches
        let probe = tokens[0];
        let mut ref2 = DecodeCache::new(&model.cfg, len + 1);
        let mut paged2 = PagedKv::new(&model.cfg, block, len + 1);
        for &tok in &tokens {
            model.decode_step(&params, tok, &mut ref2);
        }
        model.prefill_chunk(&params, &tokens, &mut paged2);
        let a = model.decode_step(&params, probe, &mut ref2);
        let b = model.decode_step(&params, probe, &mut paged2);
        if a != b {
            return Err("probe after chunked prefill diverges".into());
        }
        Ok(())
    });
}

fn greedy_engine(cfg: &ModelConfig, params: &Params, e: EngineConfig) -> Engine {
    Engine::new(cfg.clone(), params.clone(), e)
}

#[test]
fn prefix_shared_sequences_diverge_correctly_after_cow() {
    // requests extending a cached prompt adopt its chain mid-block (CoW),
    // and their outputs must match an engine that never shares anything
    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(11);
    let base = EngineConfig {
        max_batch: 4,
        kv_block: 4,
        kv_blocks: 64,
        prefill_chunk: 8,
        threads: 2,
        ..EngineConfig::default()
    };
    // 13 shared tokens: not block-aligned, so adopters append mid-block
    let shared: Vec<usize> = (0..13).map(|k| (k * 11 + 2) % 50).collect();
    let run = |prefix_cache: bool| {
        let mut e = greedy_engine(
            &cfg,
            &params,
            EngineConfig { prefix_cache, ..base.clone() },
        );
        e.enqueue(GenRequest::greedy(99, shared.clone(), 3)).unwrap();
        let mut out = e.run_to_completion(); // publishes the shared chain
        for id in 0..4u64 {
            let mut p = shared.clone();
            p.push(10 + id as usize); // diverge right after the shared prefix
            p.push(5);
            e.enqueue(GenRequest::greedy(id, p, 5)).unwrap();
        }
        out.extend(e.run_to_completion());
        out.sort_by_key(|r| r.id);
        (e, out)
    };
    let (cached, a) = run(true);
    let (plain, b) = run(false);
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.id, y.id);
        assert_eq!(
            x.tokens, y.tokens,
            "req {}: copy-on-write divergence corrupted decoding",
            x.id
        );
    }
    assert!(cached.stats.prefix_hits() >= 4, "extensions must hit the cached prompt");
    assert!(cached.cow_copies() > 0, "mid-block adoption must trigger copy-on-write");
    assert_eq!(plain.stats.prefix_hits(), 0);
    let (live, ..) = cached.kv_usage();
    let idx = cached.prefix_cache_stats();
    assert!(idx.entries > 0);
    assert!(live > 0, "prefix index keeps published chains alive");
}

#[test]
fn preempt_then_reprefill_matches_unpreempted_run() {
    // a 6-block arena against sequences needing 3 blocks each forces
    // preemption + re-prefill; greedy outputs must match a roomy engine
    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(12);
    let reqs: Vec<GenRequest> = (0..5)
        .map(|id| {
            let prompt: Vec<usize> = (0..10).map(|k| (id as usize * 7 + k * 3 + 1) % 50).collect();
            GenRequest::greedy(id, prompt, 8)
        })
        .collect();
    let run = |kv_blocks: usize| {
        let mut e = greedy_engine(
            &cfg,
            &params,
            EngineConfig {
                max_batch: 4,
                kv_block: 8,
                kv_blocks,
                prefill_chunk: 4,
                prefix_cache: false,
                threads: 1,
                ..EngineConfig::default()
            },
        );
        for r in &reqs {
            e.enqueue(r.clone()).unwrap();
        }
        let mut out = e.run_to_completion();
        out.sort_by_key(|r| r.id);
        (e, out)
    };
    let (tight, a) = run(6);
    let (roomy, b) = run(0);
    assert_eq!(a.len(), 5);
    assert!(tight.stats.preemptions() > 0, "tight arena must preempt");
    assert_eq!(roomy.stats.preemptions(), 0);
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens, y.tokens, "req {}: re-prefill changed the completion", x.id);
        assert_eq!(x.tokens.len(), 8);
    }
    let (live_t, ..) = tight.kv_usage();
    let (live_r, ..) = roomy.kv_usage();
    assert_eq!(live_t, 0, "tight arena leaked blocks");
    assert_eq!(live_r, 0, "roomy arena leaked blocks");
}

#[test]
fn preemption_with_prefix_cache_still_correct() {
    // preemption and prefix sharing interact: preempted sequences re-adopt
    // cached chains on re-admission; outputs must stay equal to a serial
    // uncached engine
    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let model = Transformer::new(cfg.clone());
    let params = model.init_params(13);
    let shared: Vec<usize> = (0..9).map(|k| (k * 5 + 3) % 50).collect();
    let reqs: Vec<GenRequest> = (0..5)
        .map(|id| {
            let mut p = shared.clone();
            p.push(15 + id as usize);
            GenRequest::greedy(id, p, 6)
        })
        .collect();
    let run = |kv_blocks: usize, prefix_cache: bool, max_batch: usize| {
        let mut e = greedy_engine(
            &cfg,
            &params,
            EngineConfig {
                max_batch,
                kv_block: 4,
                kv_blocks,
                prefill_chunk: 4,
                prefix_cache,
                threads: 1,
                ..EngineConfig::default()
            },
        );
        for r in &reqs {
            e.enqueue(r.clone()).unwrap();
        }
        let mut out = e.run_to_completion();
        out.sort_by_key(|r| r.id);
        (e, out)
    };
    let (contended, a) = run(8, true, 4); // 8 blocks, 4-block sequences
    let (reference, b) = run(0, false, 1);
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.tokens, y.tokens, "req {}: contention + sharing broke decoding", x.id);
    }
    // under contention something must have given: either preemption or
    // LRU eviction of cached prefixes
    assert!(
        contended.stats.preemptions() > 0 || contended.prefix_cache_stats().evictions > 0,
        "8-block arena with 4-block sequences should show contention"
    );
}
