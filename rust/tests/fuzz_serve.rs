//! Serving fuzz/conformance suite — drives `testing::fuzz::check_case`
//! over a fixed seed matrix. Each case generates a random request mix
//! (shared prefixes, varied prompt/gen lengths) and a random engine
//! configuration (tiny arenas forcing preemption + copy-on-write, random
//! block/chunk/thread counts) under a random KV storage scheme
//! (`f32` / `fp8_e3m4` / `int8_sr` / `fp4_e2m1_sr` — the last exercises
//! sub-byte packed codes) and asserts:
//!
//! * every request completes and zero arena blocks leak after drain;
//! * identical runs reproduce identical greedy tokens (incl. SR KV);
//! * prefix cache on/off never changes greedy outputs;
//! * paged `f32` serving is bit-identical to the contiguous reference;
//! * quantized-KV logit drift vs f32 stays bounded (per-scheme bound);
//! * enabling the f32 decode mirror (`kv_mirror`) never changes greedy
//!   outputs — the fused packed-code kernels match the mirror bit-for-bit;
//! * enabling self-speculative decoding (`spec_draft_store` = 4-bit SR
//!   draft, depth varied by seed) never changes greedy outputs and drains
//!   leak-free — exact-match acceptance + deterministic rollback;
//! * disabling wave batching (`wave_batch = false`, per-sequence decode
//!   instead of the weight-stationary batched wave) never changes greedy
//!   outputs and drains leak-free;
//! * (net arm) the same mix replayed over loopback TCP — wire codec,
//!   strict parse, framing, drain — yields bit-identical tokens with zero
//!   lost responses and zero live blocks (`check_case_net`).
//!
//! Every failure (invariant Err *or* panic inside the engine) reports the
//! generating seed: reproduce with `testing::fuzz::check_case(<seed>)`.
//!
//! `GAUSSWS_FUZZ_SEEDS=<n>` widens the matrix beyond the CI default of 8
//! (extra seeds are derived deterministically), e.g. for a soak run:
//! `GAUSSWS_FUZZ_SEEDS=200 cargo test --release --test fuzz_serve`.

use gaussws::config::schema::{Arch, ModelConfig};
use gaussws::serve::{Engine, EngineConfig, GenRequest};
use gaussws::testing::fuzz::{
    check_case, check_case_net, kv_logit_drift, model_under_test, FuzzCase, FUZZ_SEED_MATRIX,
};

fn seeds() -> Vec<u64> {
    // clamped to >= 1 so a mangled env var can never make the suite pass
    // vacuously with zero cases
    let n: usize = std::env::var("GAUSSWS_FUZZ_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(FUZZ_SEED_MATRIX.len())
        .max(1);
    (0..n)
        .map(|i| {
            if i < FUZZ_SEED_MATRIX.len() {
                FUZZ_SEED_MATRIX[i]
            } else {
                0x5EED_0000 + i as u64
            }
        })
        .collect()
}

#[test]
fn fuzz_serve_conformance_seed_matrix() {
    for seed in seeds() {
        // catch panics too (allocator expects, engine asserts) so the
        // reproducing seed is always the first thing a red run prints
        let outcome = std::panic::catch_unwind(|| check_case(seed));
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!(
                "fuzz_serve seed {seed} FAILED — reproduce with \
                 testing::fuzz::check_case({seed}): {msg}"
            ),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<String>()
                    .map(String::as_str)
                    .or_else(|| payload.downcast_ref::<&str>().copied())
                    .unwrap_or("<non-string panic>");
                panic!(
                    "fuzz_serve seed {seed} PANICKED — reproduce with \
                     testing::fuzz::check_case({seed}): {msg}"
                );
            }
        }
    }
}

#[test]
fn fuzz_serve_net_transport_seed_matrix() {
    // the net-transparency arm (invariant 7): every matrix seed's request
    // mix replayed over loopback TCP must match the in-process engine
    for seed in seeds() {
        let outcome = std::panic::catch_unwind(|| check_case_net(seed));
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!(
                "fuzz_serve net seed {seed} FAILED — reproduce with \
                 testing::fuzz::check_case_net({seed}): {msg}"
            ),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<String>()
                    .map(String::as_str)
                    .or_else(|| payload.downcast_ref::<&str>().copied())
                    .unwrap_or("<non-string panic>");
                panic!(
                    "fuzz_serve net seed {seed} PANICKED — reproduce with \
                     testing::fuzz::check_case_net({seed}): {msg}"
                );
            }
        }
    }
}

#[test]
fn seed_matrix_covers_every_kv_scheme() {
    // the fixed CI matrix must exercise all four storage schemes (incl.
    // the sub-byte fp4 stratum); if the generator changes, rebalance
    // FUZZ_SEED_MATRIX. Deliberately checks the constant matrix, not
    // seeds(): narrowing GAUSSWS_FUZZ_SEEDS to bisect one red seed must
    // not fail this unrelated test
    let mut labels: Vec<&str> =
        FUZZ_SEED_MATRIX.iter().map(|&s| FuzzCase::generate(s).kv_label).collect();
    labels.sort_unstable();
    labels.dedup();
    assert!(
        labels.len() >= 4,
        "seed matrix only covers kv schemes {labels:?}; rebalance FUZZ_SEED_MATRIX"
    );
}

#[test]
fn quantized_kv_preemption_storm_is_leak_free() {
    // a directed worst case on top of the random matrix: 6 requests of 3
    // blocks each against a 4-block fp8 arena — sequences must take turns
    // via preemption, and the quantized arena must come back empty
    let (model, params) = model_under_test();
    let cfg = ModelConfig::tiny(Arch::Gpt2);
    let mut e = Engine::new(
        cfg,
        params,
        EngineConfig {
            max_batch: 4,
            kv_block: 8,
            kv_blocks: 4,
            prefill_chunk: 4,
            prefix_cache: false,
            threads: 1,
            kv_scheme: gaussws::quant::resolve("fp8_e3m4").unwrap(),
            ..EngineConfig::default()
        },
    );
    for id in 0..6u64 {
        let prompt: Vec<usize> = (0..12).map(|k| (id as usize * 5 + k * 3) % 50).collect();
        e.enqueue(GenRequest::greedy(id, prompt, 6)).unwrap();
    }
    let out = e.run_to_completion();
    assert_eq!(out.len(), 6);
    assert!(e.stats.preemptions() > 0, "4-block arena with 3-block sequences must preempt");
    let (live, ..) = e.kv_usage();
    assert_eq!(live, 0, "quantized blocks leaked through preemption");
}

#[test]
fn prop_quantized_prefill_is_chunk_split_invariant() {
    // rows are encoded at stage time, so feeding a prompt in chunks of any
    // size must give bit-identical logits to token-at-a-time — for every
    // KV scheme, not just f32
    use gaussws::nn::kv::{KvQuant, PagedKv};
    use gaussws::testing::prop::{check, Gen};
    let (model, params) = model_under_test();
    check("quantized chunked prefill == token-by-token", 10, |g: &mut Gen| {
        let kv_label = *g.choose(gaussws::testing::fuzz::FUZZ_KV_LABELS);
        let kv_block = *g.choose(&[2usize, 4, 8]);
        let len = g.usize_in(2, 20);
        let tokens: Vec<usize> = (0..len).map(|_| g.usize_in(0, model.cfg.vocab - 1)).collect();
        let seed = g.u64();
        let mk = || {
            let q = KvQuant::new(
                gaussws::quant::resolve(kv_label).unwrap(),
                model.cfg.d_model,
                seed,
            )
            .unwrap();
            PagedKv::new_quantized(&model.cfg, kv_block, len + 1, q)
        };
        let mut reference = mk();
        let mut want = Vec::new();
        for &t in &tokens {
            want = model.decode_step(&params, t, &mut reference);
        }
        let mut chunked = mk();
        let mut got = Vec::new();
        let mut fed = 0;
        while fed < len {
            let chunk = g.usize_in(1, len - fed);
            got = model.prefill_chunk(&params, &tokens[fed..fed + chunk], &mut chunked);
            fed += chunk;
        }
        if got != want {
            return Err(format!("{kv_label} block {kv_block} len {len}: chunk split changed logits"));
        }
        // the caches agree beyond the last logits row: one more identical
        // probe token must decode identically from both
        let a = model.decode_step(&params, tokens[0], &mut reference);
        let b = model.decode_step(&params, tokens[0], &mut chunked);
        if a != b {
            return Err(format!("{kv_label} block {kv_block} len {len}: probe diverged"));
        }
        Ok(())
    });
}

#[test]
fn quantized_drift_is_nonzero_and_bounded_per_scheme() {
    let (model, params) = model_under_test();
    let tokens: Vec<usize> = (0..16).map(|k| (k * 13 + 5) % 50).collect();
    let drift_of = |label: &str| kv_logit_drift(&model, &params, &tokens, label, 4, 3);
    assert_eq!(drift_of("f32"), 0.0);
    for label in ["fp8_e3m4", "int8_sr", "fp4_e2m1_sr"] {
        let d = drift_of(label);
        assert!(d.is_finite() && d > 0.0, "{label}: drift {d}");
        let bound = gaussws::testing::fuzz::drift_bound(label);
        assert!(d < bound, "{label}: drift {d} exceeds bound {bound}");
    }
}
