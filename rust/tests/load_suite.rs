//! End-to-end suite for the declarative workload framework (`load`): the
//! scenario corpus runs against the tiny reference model through every
//! driver, each emitting a distinct BENCH_serve arm with telemetry-backed
//! latency percentiles, and the TOML spec path round-trips into a run.

use gaussws::load::{run, run_scenario, tiny_model, Driver, Scenario, WorkloadSpec};
use gaussws::serve::{EngineConfig, FinishReason, NetServerConfig};
use std::collections::BTreeSet;

const MODEL_SEED: u64 = 11;

#[test]
fn every_scenario_runs_and_emits_a_distinct_bench_arm() {
    let mut labels = BTreeSet::new();
    for sc in Scenario::all() {
        // Direct: fully deterministic scheduling, maximum concurrency
        let outcome = run_scenario(&sc, Driver::Direct, MODEL_SEED)
            .unwrap_or_else(|e| panic!("{}: {e:#}", sc.spec.name));
        assert_eq!(
            outcome.responses.len() + outcome.failed,
            sc.spec.requests,
            "{}: requests lost",
            sc.spec.name
        );
        assert_eq!(outcome.failed, 0, "{}: requests failed", sc.spec.name);
        assert_eq!(outcome.stats.blocks_live_now(), 0.0, "{}: blocks leaked", sc.spec.name);
        let arm = outcome.bench_arm(&sc.spec, Driver::Direct.label());
        // telemetry-backed percentiles are present in every arm
        for key in ["p50_total_ms", "p95_total_ms", "p99_total_ms", "p50_ttft_ms"] {
            assert!(
                arm.get(key).as_f64().is_some(),
                "{}: bench arm missing {key}",
                sc.spec.name
            );
        }
        assert_eq!(arm.get("workload").as_str(), Some(sc.spec.name.as_str()));
        let label = arm.get("label").as_str().expect("label").to_string();
        assert!(labels.insert(label.clone()), "duplicate bench label {label}");
    }
    assert_eq!(labels.len(), Scenario::all().len());
}

#[test]
fn preemption_storm_actually_preempts() {
    let sc = Scenario::by_name("preemption-storm").unwrap();
    let outcome = run_scenario(&sc, Driver::Direct, MODEL_SEED).unwrap();
    assert_eq!(outcome.responses.len(), sc.spec.requests);
    assert!(
        outcome.stats.preemptions() > 0,
        "a 6-block arena with 3-block sequences must preempt (got {})",
        outcome.stats.preemptions()
    );
}

#[test]
fn bursty_chat_exercises_the_prefix_cache_and_deadline_mix() {
    let sc = Scenario::by_name("bursty-chat").unwrap();
    // the spec itself must carry the mixture features
    assert!(sc.spec.shared_prefix_len >= sc.kv_block, "prefix sharing is block-granular");
    assert!(sc.spec.deadline_ms.is_some());
    let reqs = sc.spec.generate();
    assert!(reqs.iter().any(|r| r.req.deadline_ms.is_some()), "deadline mix generated none");
    assert!(reqs.iter().any(|r| r.req.deadline_ms.is_none()), "deadline mix hit every request");
    assert!(reqs.iter().any(|r| r.delay_ms > 0), "burst schedule generated no gaps");
    let outcome = run_scenario(&sc, Driver::Direct, MODEL_SEED).unwrap();
    assert_eq!(outcome.responses.len(), sc.spec.requests);
    assert!(outcome.stats.prefix_lookups() > 0, "prefix cache never consulted");
}

#[test]
fn many_short_is_transport_invariant() {
    // no deadlines, roomy arena: direct, in-process and TCP must produce
    // bit-identical greedy tokens for the whole scenario
    let sc = Scenario::by_name("many-short").unwrap();
    let direct = run_scenario(&sc, Driver::Direct, MODEL_SEED).unwrap();
    let inproc = run_scenario(&sc, Driver::InProcess, MODEL_SEED).unwrap();
    let tcp = run_scenario(&sc, Driver::Tcp(NetServerConfig::default()), MODEL_SEED).unwrap();
    assert_eq!(direct.responses.len(), sc.spec.requests);
    for other in [&inproc, &tcp] {
        assert_eq!(other.responses.len(), sc.spec.requests);
        assert_eq!(other.failed, 0);
        for (a, b) in direct.responses.iter().zip(other.responses.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "req {}: driver changed the tokens", a.id);
        }
    }
    assert_eq!(tcp.stats.blocks_live_now(), 0.0);
}

#[test]
fn tcp_scenario_accounts_for_every_request() {
    let sc = Scenario::by_name("bursty-chat").unwrap();
    let outcome = run_scenario(&sc, Driver::Tcp(NetServerConfig::default()), MODEL_SEED).unwrap();
    assert_eq!(
        outcome.responses.len() + outcome.failed,
        sc.spec.requests,
        "tcp run lost requests"
    );
    assert_eq!(outcome.failed, 0);
    // deadline-expired completions (if any) are completions, not losses
    for r in &outcome.responses {
        assert!(matches!(r.finish, FinishReason::Length | FinishReason::Deadline));
    }
    assert_eq!(outcome.stats.blocks_live_now(), 0.0);
}

#[test]
fn toml_spec_drives_a_run_end_to_end() {
    let text = "\
[workload]
name = \"toml-smoke\"
clients = 2
requests = 6
prompt_len = \"uniform 2 6\"
max_new = \"fixed 3\"
arrival = \"paced 1\"
seed = 5
";
    let doc = gaussws::config::toml::parse(text).unwrap();
    let spec = WorkloadSpec::from_toml(&doc).unwrap();
    assert_eq!(spec.name, "toml-smoke");
    let (cfg, params) = tiny_model(MODEL_SEED);
    let ecfg = EngineConfig {
        max_batch: 4,
        kv_block: 8,
        prefill_chunk: 4,
        threads: 1,
        ..EngineConfig::default()
    };
    let outcome = run(&spec, cfg, params, ecfg, Driver::InProcess).unwrap();
    assert_eq!(outcome.responses.len(), 6);
    assert_eq!(outcome.failed, 0);
    for r in &outcome.responses {
        assert_eq!(r.tokens.len(), 3);
    }
    let arm = outcome.bench_arm(&spec, Driver::InProcess.label());
    assert_eq!(arm.get("workload").as_str(), Some("toml-smoke"));
    assert_eq!(arm.get("driver").as_str(), Some("in-process"));
}
