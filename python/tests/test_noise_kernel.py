"""L1 correctness: the Pallas noise kernels vs the pure-jnp oracle, plus
distributional checks against the Eq. 10 probabilities."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import noise, ref


@settings(max_examples=20, deadline=None)
@given(
    groups=st.sampled_from([32, 64, 512, 1024, 1536]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_bitwise_kernel_matches_ref(groups, seed):
    bits = jax.random.bits(jax.random.PRNGKey(seed), (groups, 4), jnp.uint32)
    kernel = noise.bitwise_noise(bits)
    oracle = ref.noise_planes_fast(bits).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(kernel), np.asarray(oracle))


def test_exact_ref_construction_probabilities():
    bits = jax.random.bits(jax.random.PRNGKey(0), (40_000, 16), jnp.uint32)
    r = np.asarray(ref.noise_planes_exact(bits)).ravel()
    p0, p1, p2 = ref.eq10_probabilities()
    n = r.size
    assert abs((r == 0).mean() - p0) < 3e-3
    assert abs((r == 1).mean() - p1) < 2e-3
    assert abs((r == -1).mean() - p1) < 2e-3
    assert abs((r == 2).mean() - p2) < 5e-4
    assert abs((r == -2).mean() - p2) < 5e-4
    assert set(np.unique(r)).issubset({-2, -1, 0, 1, 2})


def test_fast_construction_probabilities():
    bits = jax.random.bits(jax.random.PRNGKey(1), (40_000, 4), jnp.uint32)
    r = np.asarray(noise.bitwise_noise(bits)).ravel()
    p0, p1, p2 = ref.eq10_probabilities()
    assert abs((r == 0).mean() - p0) < 3e-3
    assert abs((r == 1).mean() - p1) < 2e-3
    assert abs((r == 2).mean() - p2) < 5e-4


def test_box_muller_matches_exact_rounded_normal():
    bits = jax.random.bits(jax.random.PRNGKey(2), (40_000, 32), jnp.uint32)
    r = np.asarray(noise.box_muller_noise(bits)).ravel()
    # exact rounded normal: Pr(0) = P(|N|<1) ~ 0.6827, Pr(±1) ~ 0.1573
    assert abs((r == 0).mean() - 0.6827) < 5e-3
    assert abs((r == 1).mean() - 0.1573) < 4e-3
    assert abs((r == -1).mean() - 0.1573) < 4e-3


def test_noise_matrix_shape_and_determinism():
    a = noise.noise_matrix(jax.random.PRNGKey(5), 64, 96)
    b = noise.noise_matrix(jax.random.PRNGKey(5), 64, 96)
    c = noise.noise_matrix(jax.random.PRNGKey(6), 64, 96)
    assert a.shape == (64, 96)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_uniform_matrix_range():
    u = np.asarray(noise.uniform_matrix(jax.random.PRNGKey(7), 64, 64))
    assert (u >= -0.5).all() and (u <= 0.5).all()
    assert abs(u.mean()) < 5e-3
    # bf16-rounded: every value is representable in bf16
    assert (u.astype(jnp.bfloat16).astype(np.float32) == u).all()


def test_mean_zero_variance_matches_target():
    r = np.asarray(noise.noise_matrix(jax.random.PRNGKey(8), 512, 512)).ravel()
    p0, p1, p2 = ref.eq10_probabilities()
    var_target = 2 * (p1 + 4 * p2)
    assert abs(r.mean()) < 5e-3
    assert abs(r.var() - var_target) < 5e-3


@pytest.mark.parametrize("words,fn", [(4, noise.bitwise_noise), (32, noise.box_muller_noise)])
def test_kernels_are_jittable_and_stable(words, fn):
    bits = jax.random.bits(jax.random.PRNGKey(3), (512, words), jnp.uint32)
    eager = fn(bits)
    jitted = jax.jit(fn)(bits)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))
