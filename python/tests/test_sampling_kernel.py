"""L1 correctness: the Eq. 3 Pallas sampling kernel vs the jnp oracle, and
the Eq. 4 custom VJP vs both the closed form and finite differences."""

import math

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import gaussws, noise, ref


def _setup(m, n, seed, bt_val=None):
    kw, kr, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    w = jax.random.normal(kw, (m, n), jnp.float32)
    r = noise.noise_matrix(kr, m, n)
    if bt_val is None:
        bt = jax.random.uniform(kb, (m // 32, n // 32), jnp.float32, 3.0, 8.0)
    else:
        bt = jnp.full((m // 32, n // 32), bt_val, jnp.float32)
    return w, bt, r


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([32, 64, 96, 256]),
    n=st.sampled_from([32, 64, 128]),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_kernel_matches_oracle_bitexact(m, n, seed):
    w, bt, r = _setup(m, n, seed)
    kernel = gaussws.sample_fwd_kernel(w, bt, r)
    oracle = ref.gaussws_sample(w, bt, r)
    assert kernel.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(kernel, np.float32), np.asarray(oracle, np.float32)
    )


def test_zero_noise_is_pure_bf16_cast():
    w, bt, _ = _setup(64, 64, 0)
    zero = jnp.zeros_like(w)
    what = gaussws.sample_fwd_kernel(w, bt, zero)
    np.testing.assert_array_equal(
        np.asarray(what, np.float32), np.asarray(w.astype(jnp.bfloat16), np.float32)
    )


def test_bt_scaling_halves_noise_per_bit():
    w, _, r = _setup(64, 64, 1)
    for lo, hi in [(3.0, 4.0), (5.0, 7.0)]:
        bt_lo = jnp.full((2, 2), lo)
        bt_hi = jnp.full((2, 2), hi)
        pqn_lo = np.asarray(gaussws.sample_fwd_kernel(w, bt_lo, r), np.float32) - np.asarray(w)
        pqn_hi = np.asarray(gaussws.sample_fwd_kernel(w, bt_hi, r), np.float32) - np.asarray(w)
        # average magnitudes scale like 2^(hi-lo) (bf16 rounding adds slack)
        ratio = np.abs(pqn_lo).mean() / max(np.abs(pqn_hi).mean(), 1e-12)
        assert 2 ** (hi - lo) * 0.7 < ratio < 2 ** (hi - lo) * 1.4, ratio


def test_vjp_matches_eq4_closed_form():
    w, bt, r = _setup(96, 64, 2)

    def loss(w_, bt_):
        what = gaussws.pq_sample(w_, bt_, r)
        return (what.astype(jnp.float32) ** 2).sum() / 2.0

    gw, gbt = jax.grad(loss, argnums=(0, 1))(w, bt)
    what32 = gaussws.pq_sample(w, bt, r).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(what32), rtol=1e-6)
    expect = ref.gaussws_bt_grad(w, bt, r, what32)
    np.testing.assert_allclose(np.asarray(gbt), np.asarray(expect), rtol=1e-5)


def test_bt_grad_matches_finite_differences():
    # FD on the *uncast* formula (the bf16 rounding makes the true loss a
    # step function; Eq. 4 differentiates the underlying smooth map).
    # f64 numpy math: central differences in f32 lose ~1% to cancellation.
    w_j, _, r_j = _setup(32, 32, 3)
    w = np.asarray(w_j, np.float64)
    r = np.asarray(r_j, np.float64)
    amax = np.abs(w).max()

    def smooth_loss(btv):
        what = w + r * (amax * 2.0 ** (1.0 - btv))
        return (what**2).sum() / 2.0

    bt0 = 5.0
    what0 = w + r * amax * 2.0 ** (1 - bt0)
    analytic = -math.log(2.0) * amax * 2.0 ** (1 - bt0) * (what0 * r).sum()
    h = 1e-5
    fd = (smooth_loss(bt0 + h) - smooth_loss(bt0 - h)) / (2 * h)
    np.testing.assert_allclose(analytic, fd, rtol=1e-6)
    # and the jnp closed form agrees with the numpy closed form
    jnp_grad = ref.gaussws_bt_grad(
        w_j, jnp.full((1, 1), bt0, jnp.float32), r_j, jnp.asarray(what0, jnp.float32)
    )
    np.testing.assert_allclose(float(jnp_grad[0, 0]), analytic, rtol=1e-3)


def test_noise_gets_no_gradient():
    w, bt, r = _setup(32, 32, 4)

    def loss(r_):
        return gaussws.pq_sample(w, bt, r_).astype(jnp.float32).sum()

    gr = jax.grad(loss)(r)
    np.testing.assert_array_equal(np.asarray(gr), 0.0)


def test_gaussws_layer_end_to_end():
    w = jax.random.normal(jax.random.PRNGKey(9), (128, 64))
    bt = jnp.full((4, 2), 4.0)
    what, r = gaussws.gaussws_layer(w, bt, jax.random.PRNGKey(10))
    assert what.shape == w.shape and what.dtype == jnp.bfloat16
    assert set(np.unique(np.asarray(r))).issubset({-2.0, -1.0, 0.0, 1.0, 2.0})
    # reproducible per key
    what2, _ = gaussws.gaussws_layer(w, bt, jax.random.PRNGKey(10))
    np.testing.assert_array_equal(
        np.asarray(what, np.float32), np.asarray(what2, np.float32)
    )


def test_stochastic_precision_annealing_prop4():
    """Proposition 4 at the op level: tiny |w| elements are masked by the
    bf16 cast with probability ~ 1-p when R != 0, preserved when R = 0."""
    m = n = 256
    # one block owner sets amax=1; everything else is tiny eps
    eps = 2.0**-20
    w = jnp.full((m, n), eps, jnp.float32).at[0, 0].set(1.0)
    bt = jnp.full((m // 32, n // 32), 4.0)
    r = noise.noise_matrix(jax.random.PRNGKey(11), m, n)
    what = np.asarray(gaussws.sample_fwd_kernel(w, bt, r), np.float32)
    rr = np.asarray(r)
    pqn_only = rr * 2.0 ** (1 - 4.0)  # amax=1 in block (0,0)
    # analysis only applies inside block (0,0), where amax = 1 (the other
    # blocks have amax = eps, so their PQN is eps-scaled too)
    blk0 = np.zeros((m, n), bool)
    blk0[:32, :32] = True
    blk0[0, 0] = False  # the amax owner itself
    mask = (rr != 0) & blk0
    # where R != 0: eps underflows -> what == bf16(PQN alone)
    lost = (
        what[mask] == pqn_only[mask].astype(jnp.bfloat16).astype(np.float32)
    ).mean()
    assert lost > 0.99, lost
    # where R == 0: eps survives the bf16 cast exactly
    keep = (rr == 0) & blk0
    assert (what[keep] == np.float32(eps)).all()
