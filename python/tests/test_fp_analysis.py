"""Section 3.3 analysis in jnp: the fp_{e,m} cast emulator vs ml_dtypes
ground truth, and Lemma 1/2 + Proposition 3/4 numerics."""

import math

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


@pytest.fixture(autouse=True)
def _x64():
    """fp_{e,m} emulation needs f64 precision; scope it to this module so
    the uint32 bit-twiddling tests elsewhere keep default 32-bit semantics."""
    with jax.enable_x64(True):
        yield


@pytest.mark.parametrize(
    "em,np_dtype",
    [
        ((8, 7), ml_dtypes.bfloat16),
        ((5, 10), np.float16),
        ((5, 2), ml_dtypes.float8_e5m2),
        ((4, 3), ml_dtypes.float8_e4m3fn),
        ((2, 1), ml_dtypes.float4_e2m1fn),
    ],
)
def test_fp_cast_matches_ml_dtypes(em, np_dtype):
    e, m = em
    rng = np.random.default_rng(0)
    # stay within the format's finite range to avoid inf-policy differences
    info = ml_dtypes.finfo(np_dtype)
    x = rng.normal(size=4096).astype(np.float64) * float(info.max) / 8
    ours = np.asarray(ref.fp_cast(jnp.asarray(x), e, m))
    truth = x.astype(np_dtype).astype(np.float64)
    np.testing.assert_allclose(ours, truth, rtol=0, atol=0)


def test_fp_cast_subnormals_bf16():
    # values below bf16 min-subnormal/2 round to zero; above survive
    min_sub = 2.0 ** (-126 - 7)
    x = jnp.asarray([min_sub * 0.49, min_sub * 0.51, min_sub])
    out = np.asarray(ref.fp_cast(x, 8, 7))
    assert out[0] == 0.0
    assert out[1] != 0.0
    assert out[2] == min_sub


def test_lemma1_bound_bf16():
    """PQN survives fp_{8,7} iff b_t < m + 2 + tau = 9 (rounded normal)."""
    m_bits = 7
    for bt, should_survive in [(8.0, True), (11.0, False)]:
        # adversarial w at the top of a binade; smallest noise |R| = 1
        w = 1.999
        pqn = 1.0 * w * 2.0 ** (1 - bt)  # amax ~= w
        cast = lambda v: float(ref.fp_cast(jnp.asarray([v]), 8, m_bits)[0])
        survived = cast(w + pqn) != cast(w)
        assert survived == should_survive, (bt, survived)


def test_lemma2_threshold():
    """eps survives iff xi > floor(tau+2-bt+log2 amax) - m."""
    m_bits, bt = 7, 4.0
    xi_bound = math.floor(0 + 2 - bt + 0) - m_bits  # amax = 1
    pqn = 2.0 ** (1 - bt)  # smallest positive noise contribution
    cast = lambda v: float(ref.fp_cast(jnp.asarray([v]), 8, m_bits)[0])
    eps_ok = 2.0 ** (xi_bound + 1)
    assert cast(eps_ok + pqn) != cast(pqn)
    eps_bad = 2.0 ** (xi_bound - 3)
    assert cast(eps_bad + pqn) == cast(pqn)


def test_prop3_fp6_suffices_for_bt4():
    """b_t = 4: Table C.1 row says ŵ fits FP6_e3m2. Sample the op and cast
    the result to e3m2 — the PQN must survive the cast."""
    from compile.kernels import gaussws, noise

    w = jax.random.normal(jax.random.PRNGKey(0), (32, 32), jnp.float32) * 0.02
    bt = jnp.full((1, 1), 4.0)
    r = noise.noise_matrix(jax.random.PRNGKey(1), 32, 32)
    what = np.asarray(gaussws.sample_fwd_kernel(w, bt, r), np.float32)
    # normalize by the block scale so the e3m2 dynamic range is used as the
    # MX container would (per-block power-of-two scale)
    scale = 2.0 ** np.ceil(np.log2(np.abs(what).max() / 28.0))  # e3m2 max=28
    casted = np.asarray(ref.fp_cast(jnp.asarray(what / scale), 3, 2)) * scale
    rr = np.asarray(r)
    # where noise fired, the cast ŵ must still differ from the cast w
    w_cast = np.asarray(ref.fp_cast(jnp.asarray(np.asarray(w) / scale), 3, 2)) * scale
    changed = (casted != w_cast)[rr != 0].mean()
    assert changed > 0.95, changed


@settings(max_examples=25, deadline=None)
@given(
    e=st.integers(min_value=2, max_value=8),
    m=st.integers(min_value=1, max_value=10),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_fp_cast_idempotent(e, m, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=256))
    once = ref.fp_cast(x, e, m)
    twice = ref.fp_cast(once, e, m)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


def test_prop4_annealing_probability():
    """Masked fraction of sub-threshold eps equals Pr(R != 0) ~ 0.283."""
    from compile.kernels import noise

    n = 512
    r = np.asarray(noise.noise_matrix(jax.random.PRNGKey(3), n, n))
    p0, _, _ = ref.eq10_probabilities()
    # empirical Pr(R=0)
    assert abs((r == 0).mean() - p0) < 5e-3
    # masked fraction = Pr(R != 0)
    assert abs((r != 0).mean() - (1 - p0)) < 5e-3
