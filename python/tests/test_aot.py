"""AOT path tests: lowering produces parseable HLO text with the expected
signature, and the manifest agrees with eval_shape."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_op_artifact_lowers_to_hlo_text():
    arts = {a.name: a for a in aot.default_artifacts()}
    a = arts["op.gaussws_sample"]
    text = a.lower_text()
    assert text.startswith("HloModule"), text[:60]
    # return_tuple=True -> root is a tuple
    assert "ROOT" in text
    assert len(text) > 1000


def test_signature_flattening_order():
    """dict args flatten in sorted-key order — the rust side depends on it."""
    tree = ({"b": jax.ShapeDtypeStruct((2,), jnp.float32),
             "a": jax.ShapeDtypeStruct((3,), jnp.float32)},
            jax.ShapeDtypeStruct((), jnp.int32))
    sig = aot._sig(tree)
    names = [s["name"] for s in sig]
    assert names == ["0/a", "0/b", "1"]
    assert sig[0]["shape"] == [3]
    assert sig[2]["dtype"] == "s32"


def test_default_artifact_set_is_complete():
    names = {a.name for a in aot.default_artifacts()}
    # every experiment's needs are present
    for required in [
        "op.noise_bitwise",
        "op.noise_boxmuller",
        "op.gaussws_sample",
        "tiny_gpt2.bf16.train",
        "tiny_gpt2.gaussws_all.train",
        "tiny_gpt2.gaussws_qkv.train",
        "tiny_gpt2.gaussws_od.train",
        "tiny_gpt2.diffq_all.train",
        "tiny_llama2.gaussws_all.train",
        "tiny_llama2.gaussws_b8t6.train",
        "small_gpt2.gaussws_all.train",
        "small_gpt2.bf16.train",
        "small_llama2.diffq_all.train",
    ]:
        assert required in names, required


def test_train_artifact_signature_matches_eval_shape():
    arts = {a.name: a for a in aot.default_artifacts()}
    a = arts["tiny_gpt2.gaussws_all.train"]
    out_tree = jax.eval_shape(a.fn, *a.example_args)
    out_sig = aot._sig(out_tree)
    # loss + one grad per param + one grad per bi
    meta = a.meta
    assert len(out_sig) == 1 + len(meta["param_names"]) + len(meta["bi_names"])
    assert out_sig[0]["shape"] == []  # loss scalar first


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_written_manifest_consistency():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    arts = manifest["artifacts"]
    assert len(arts) >= 20
    for name, entry in arts.items():
        path = os.path.join(ARTIFACTS, entry["file"])
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(80)
        assert head.startswith("HloModule"), name
        assert entry["inputs"], name
        assert entry["outputs"], name


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_train_signature_counts():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        arts = json.load(f)["artifacts"]
    e = arts["tiny_gpt2.gaussws_all.train"]
    n_params = len(e["meta"]["param_names"])
    n_bi = len(e["meta"]["bi_names"])
    assert len(e["inputs"]) == n_params + n_bi + 3  # + x, y, seed
    assert len(e["outputs"]) == 1 + n_params + n_bi
