"""L2 model tests: shapes, init-loss sanity, PQT wiring, gradient flow,
policy resolution and step determinism per seed."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

TINY = M.ModelCfg()
TINY_L = M.ModelCfg(arch="llama2")


def _batch(cfg, seed=0, b=2, t=16):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.randint(kx, (b, t), 0, cfg.vocab, jnp.int32)
    y = jax.random.randint(ky, (b, t), 0, cfg.vocab, jnp.int32)
    return x, y


@pytest.mark.parametrize("cfg", [TINY, TINY_L], ids=["gpt2", "llama2"])
def test_forward_shapes_and_finite(cfg):
    pqt = M.PqtCfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    bi = M.init_bi(cfg, pqt)
    x, _ = _batch(cfg)
    logits, bts = M.forward(cfg, pqt, params, bi, x, jnp.int32(3))
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert len(bts) == cfg.n_layer * len(cfg.linear_names)


@pytest.mark.parametrize("cfg", [TINY, TINY_L], ids=["gpt2", "llama2"])
@pytest.mark.parametrize("method", ["none", "gaussws", "diffq"])
def test_init_loss_near_log_vocab(cfg, method):
    pqt = M.PqtCfg(method=method)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    bi = M.init_bi(cfg, pqt)
    x, y = _batch(cfg, 1)
    loss = M.loss_fn(cfg, pqt, params, bi, x, y, jnp.int32(0))
    assert abs(float(loss) - math.log(cfg.vocab)) < 0.7


def test_policy_bi_counts():
    assert len(M.init_bi(TINY, M.PqtCfg(parts=("all",)))) == 2 * 4
    assert len(M.init_bi(TINY, M.PqtCfg(parts=("qkv",)))) == 2
    assert len(M.init_bi(TINY, M.PqtCfg(parts=("od",)))) == 2 * 2
    assert len(M.init_bi(TINY, M.PqtCfg(method="none"))) == 0
    assert len(M.init_bi(TINY_L, M.PqtCfg(parts=("all",)))) == 2 * 7


def test_bi_grid_shapes_match_weights():
    bi = M.init_bi(TINY, M.PqtCfg(parts=("all",)))
    for name, grid in bi.items():
        w_shape = TINY.linear_shape(name.split(".", 1)[1])
        assert grid.shape == (w_shape[0] // 32, w_shape[1] // 32)
        assert (np.asarray(grid) == 1.0).all()  # b_i init = 1 (§3.6)


def test_train_step_grad_flow():
    pqt = M.PqtCfg()
    params = M.init_params(TINY, jax.random.PRNGKey(2))
    bi = M.init_bi(TINY, pqt)
    x, y = _batch(TINY, 2)
    step = jax.jit(M.train_step_fn(TINY, pqt))
    loss, gp, gb = step(params, bi, x, y, jnp.int32(5))
    assert float(loss) > 0
    assert set(gp.keys()) == set(params.keys())
    assert set(gb.keys()) == set(bi.keys())
    # every weight matrix receives gradient signal
    for name, g in gp.items():
        if np.asarray(params[name]).ndim == 2:
            assert np.abs(np.asarray(g)).max() > 0, name
    # bi gradients exist and are finite (can be tiny at init)
    for name, g in gb.items():
        assert np.isfinite(np.asarray(g)).all(), name


def test_same_seed_same_loss_different_seed_differs():
    pqt = M.PqtCfg()
    params = M.init_params(TINY, jax.random.PRNGKey(3))
    bi = M.init_bi(TINY, pqt)
    x, y = _batch(TINY, 3)
    f = jax.jit(M.eval_step_fn(TINY, pqt))
    a = float(f(params, bi, x, y, jnp.int32(1)))
    b = float(f(params, bi, x, y, jnp.int32(1)))
    c = float(f(params, bi, x, y, jnp.int32(2)))
    assert a == b
    assert a != c  # different noise sample


def test_baseline_ignores_seed():
    pqt = M.PqtCfg(method="none")
    params = M.init_params(TINY, jax.random.PRNGKey(4))
    x, y = _batch(TINY, 4)
    f = jax.jit(M.eval_step_fn(TINY, pqt))
    assert float(f(params, {}, x, y, jnp.int32(1))) == float(
        f(params, {}, x, y, jnp.int32(99))
    )


def test_lambda_loss_term():
    pqt0 = M.PqtCfg(lambda_=0.0)
    pqt1 = M.PqtCfg(lambda_=1.0)
    params = M.init_params(TINY, jax.random.PRNGKey(5))
    bi = M.init_bi(TINY, pqt0)
    x, y = _batch(TINY, 5)
    l0 = float(M.loss_fn(TINY, pqt0, params, bi, x, y, jnp.int32(0)))
    l1 = float(M.loss_fn(TINY, pqt1, params, bi, x, y, jnp.int32(0)))
    # bi=1 -> b_t = b_init -> |b_t - b_target| = 2 per layer, 8 layers
    assert abs((l1 - l0) - 8 * 2.0) < 1e-3


def test_causality():
    pqt = M.PqtCfg(method="none")
    params = M.init_params(TINY, jax.random.PRNGKey(6))
    x, _ = _batch(TINY, 6, b=1, t=8)
    la, _ = M.forward(TINY, pqt, params, {}, x, jnp.int32(0))
    x2 = x.at[0, -1].set((int(x[0, -1]) + 1) % TINY.vocab)
    lb, _ = M.forward(TINY, pqt, params, {}, x2, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(la[0, :-1]), np.asarray(lb[0, :-1]))
    assert not np.array_equal(np.asarray(la[0, -1]), np.asarray(lb[0, -1]))


def test_param_names_match_rust_convention():
    params = M.init_params(TINY, jax.random.PRNGKey(7))
    for expect in ["embed", "pos_embed", "blk0.qkv", "blk1.down", "lnf.g", "lnf.b"]:
        assert expect in params, expect
    params_l = M.init_params(TINY_L, jax.random.PRNGKey(7))
    for expect in ["blk0.q", "blk0.gate", "blk1.up", "lnf.g"]:
        assert expect in params_l, expect
    assert "pos_embed" not in params_l  # llama uses rotary
