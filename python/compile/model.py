"""L2: GPT2/Llama2-style transformer forward/backward in pure JAX with PQT
linears (Pallas-backed Eq. 3 sampling, Eq. 4 custom VJP).

This module is build-time only: `aot.py` lowers `train_step` / `eval_step`
to HLO text once; the rust coordinator executes the artifacts. Parameter
names and layouts deliberately mirror `rust/src/nn/transformer.rs`
(weights are (out_features, in_features)) so checkpoints cross the
language boundary without translation.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import noise as noise_mod
from .kernels.gaussws import pq_sample
from .kernels.ref import BLOCK, bt_from_bi

# ---------------------------------------------------------------------------
# configs (mirror rust config::schema)


@dataclass(frozen=True)
class ModelCfg:
    arch: str = "gpt2"  # "gpt2" | "llama2"
    n_layer: int = 2
    d_model: int = 64
    n_head: int = 2
    d_ff: int = 128
    vocab: int = 256
    seq_len: int = 64

    def __post_init__(self):
        assert self.arch in ("gpt2", "llama2"), self.arch
        assert self.d_model % self.n_head == 0
        # PQT blocks require multiples of 32 on every linear dimension
        for dim in (self.d_model, self.d_ff, self.vocab):
            assert dim % BLOCK == 0, f"{dim} not a multiple of {BLOCK}"

    @property
    def linear_names(self):
        if self.arch == "gpt2":
            return ("qkv", "out", "up", "down")
        return ("q", "k", "v", "out", "gate", "down", "up")

    def linear_shape(self, name: str):
        d, f = self.d_model, self.d_ff
        return {
            "qkv": (3 * d, d),
            "q": (d, d),
            "k": (d, d),
            "v": (d, d),
            "out": (d, d),
            "gate": (f, d),
            "up": (f, d),
            "down": (d, f),
        }[name]


@dataclass(frozen=True)
class PqtCfg:
    method: str = "gaussws"  # "none" | "gaussws" | "diffq"
    parts: tuple = ("all",)
    b_init: float = 6.0
    b_target: float = 4.0
    lambda_: float = 0.0

    def applies(self, name: str) -> bool:
        if self.method == "none":
            return False
        parts = []
        for p in self.parts:
            parts.extend(["out", "down"] if p == "od" else [p])
        return "all" in parts or name in parts


# ---------------------------------------------------------------------------
# parameter init (names match rust)


def init_params(cfg: ModelCfg, key) -> dict:
    params = {}
    d = cfg.d_model
    resid_std = 0.02 / math.sqrt(2.0 * cfg.n_layer)
    keys = iter(jax.random.split(key, 4 + cfg.n_layer * 8))

    def randn(shape, std):
        return (jax.random.normal(next(keys), shape, jnp.float32) * std)

    params["embed"] = randn((cfg.vocab, d), 0.02)
    if cfg.arch == "gpt2":
        params["pos_embed"] = randn((cfg.seq_len, d), 0.01)
    for l in range(cfg.n_layer):
        p = f"blk{l}."
        for name in cfg.linear_names:
            std = resid_std if name in ("out", "down") else 0.02
            params[p + name] = randn(cfg.linear_shape(name), std)
        params[p + "ln1.g"] = jnp.ones((d,), jnp.float32)
        params[p + "ln2.g"] = jnp.ones((d,), jnp.float32)
        if cfg.arch == "gpt2":
            params[p + "ln1.b"] = jnp.zeros((d,), jnp.float32)
            params[p + "ln2.b"] = jnp.zeros((d,), jnp.float32)
    params["lnf.g"] = jnp.ones((d,), jnp.float32)
    if cfg.arch == "gpt2":
        params["lnf.b"] = jnp.zeros((d,), jnp.float32)
    return params


def init_bi(cfg: ModelCfg, pqt: PqtCfg) -> dict:
    """One b_i grid per PQT-enabled linear, initialized to 1 (paper §3.6)."""
    bi = {}
    if pqt.method == "none":
        return bi
    for l in range(cfg.n_layer):
        for name in cfg.linear_names:
            if pqt.applies(name):
                r, c = cfg.linear_shape(name)
                bi[f"blk{l}.{name}"] = jnp.ones((r // BLOCK, c // BLOCK), jnp.float32)
    return bi


# ---------------------------------------------------------------------------
# forward


def _norm(cfg: ModelCfg, x, g, b=None, eps=1e-5):
    if cfg.arch == "gpt2":
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        return (x - mu) / jnp.sqrt(var + eps) * g + b
    ms = (x * x).mean(-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * g


def _rope(x, theta=10000.0):
    """Rotary embedding on (B, T, H, hd) with pair rotation like rust."""
    b, t, h, hd = x.shape
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    idx = jnp.arange(0, hd, 2, dtype=jnp.float32)[None, :]
    freq = 1.0 / theta ** (idx / hd)
    ang = pos * freq  # (T, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x0, x1 = x[..., 0::2], x[..., 1::2]
    sin = sin[None, :, None, :]
    cos = cos[None, :, None, :]
    r0 = x0 * cos - x1 * sin
    r1 = x0 * sin + x1 * cos
    return jnp.stack([r0, r1], axis=-1).reshape(b, t, h, hd)


def _mm(x, w_bf16):
    """BF16 GEMM with FP32 accumulation: y = x @ w.T (paper §4 setup)."""
    return jnp.einsum(
        "...d,od->...o",
        x.astype(jnp.bfloat16),
        w_bf16,
        preferred_element_type=jnp.float32,
    )


def _linear(cfg: ModelCfg, pqt: PqtCfg, params, bi, name, x, key):
    """One (possibly PQT-sampled) linear layer. Returns (y, aux_bt_list)."""
    w = params[name]
    if pqt.applies(name.split(".", 1)[1]):
        bt = bt_from_bi(bi[name], pqt.b_init, pqt.b_target)
        m, n = w.shape
        if pqt.method == "gaussws":
            r = noise_mod.noise_matrix(key, m, n)
        else:  # diffq
            r = noise_mod.uniform_matrix(key, m, n)
        what = pq_sample(w, bt, r)
        return _mm(x, what), [bt]
    return _mm(x, w.astype(jnp.bfloat16)), []


def forward(cfg: ModelCfg, pqt: PqtCfg, params, bi, tokens, seed):
    """Logits for a (B, T) int32 token batch. `seed` is an int32 scalar;
    per-layer noise keys are derived by fold_in (the §3.6 seed tree's leaf
    level — the trunk lives in rust)."""
    B, T = tokens.shape
    d = cfg.d_model
    key = jax.random.PRNGKey(seed)
    x = params["embed"][tokens]  # (B, T, d)
    if cfg.arch == "gpt2":
        x = x + params["pos_embed"][None, :T, :]

    bts = []
    lin_idx = 0
    for l in range(cfg.n_layer):
        p = f"blk{l}."

        def lkey():
            nonlocal lin_idx
            lin_idx += 1
            return jax.random.fold_in(key, lin_idx)

        h = _norm(cfg, x, params[p + "ln1.g"], params.get(p + "ln1.b"))
        if cfg.arch == "gpt2":
            qkv, aux = _linear(cfg, pqt, params, bi, p + "qkv", h, lkey())
            bts += aux
            q, k, v = jnp.split(qkv, 3, axis=-1)
        else:
            q, aux_q = _linear(cfg, pqt, params, bi, p + "q", h, lkey())
            k, aux_k = _linear(cfg, pqt, params, bi, p + "k", h, lkey())
            v, aux_v = _linear(cfg, pqt, params, bi, p + "v", h, lkey())
            bts += aux_q + aux_k + aux_v
        hd = d // cfg.n_head
        q = q.reshape(B, T, cfg.n_head, hd)
        k = k.reshape(B, T, cfg.n_head, hd)
        v = v.reshape(B, T, cfg.n_head, hd)
        if cfg.arch == "llama2":
            q, k = _rope(q), _rope(k)
        scores = jnp.einsum("bihe,bjhe->bhij", q, k) / math.sqrt(hd)
        mask = jnp.tril(jnp.ones((T, T), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhij,bjhe->bihe", att, v).reshape(B, T, d)
        y, aux = _linear(cfg, pqt, params, bi, p + "out", ctx, lkey())
        bts += aux
        x = x + y

        h = _norm(cfg, x, params[p + "ln2.g"], params.get(p + "ln2.b"))
        if cfg.arch == "gpt2":
            u, aux = _linear(cfg, pqt, params, bi, p + "up", h, lkey())
            bts += aux
            u = jax.nn.gelu(u, approximate=True)
        else:
            gate, aux_g = _linear(cfg, pqt, params, bi, p + "gate", h, lkey())
            u, aux_u = _linear(cfg, pqt, params, bi, p + "up", h, lkey())
            bts += aux_g + aux_u
            u = u * jax.nn.silu(gate)
        dn, aux = _linear(cfg, pqt, params, bi, p + "down", u, lkey())
        bts += aux
        x = x + dn

    x = _norm(cfg, x, params["lnf.g"], params.get("lnf.b"))
    logits = _mm(x, params["embed"].astype(jnp.bfloat16))  # tied head
    return logits, bts


def loss_fn(cfg: ModelCfg, pqt: PqtCfg, params, bi, x_tok, y_tok, seed):
    """Mean next-token cross entropy (+ optional Eq. 12 λ term)."""
    logits, bts = forward(cfg, pqt, params, bi, x_tok, seed)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, y_tok[..., None], axis=-1).mean()
    if pqt.lambda_ != 0.0 and bts:
        reg = sum(jnp.abs(bt - pqt.b_target).mean() for bt in bts)
        nll = nll + pqt.lambda_ * reg
    return nll


def train_step_fn(cfg: ModelCfg, pqt: PqtCfg):
    """(params, bi, x, y, seed) -> (loss, grads_params, grads_bi).

    The rust coordinator applies the optimizer; keeping the update out of
    the artifact means one HLO serves every (optimizer, LR schedule, decay)
    configuration.
    """

    def step(params, bi, x_tok, y_tok, seed):
        (loss), grads = jax.value_and_grad(
            lambda p, b: loss_fn(cfg, pqt, p, b, x_tok, y_tok, seed), argnums=(0, 1)
        )(params, bi)
        return loss, grads[0], grads[1]

    return step


def eval_step_fn(cfg: ModelCfg, pqt: PqtCfg):
    """(params, bi, x, y, seed) -> loss (no grads)."""

    def step(params, bi, x_tok, y_tok, seed):
        return loss_fn(cfg, pqt, params, bi, x_tok, y_tok, seed)

    return step
