"""L1 Pallas kernels: Eq. 10 bitwise rounded-normal noise generation.

The paper's insight (Section 3.4): the approximated rounded normal
``R ≈ round(N(0,1)/2)`` needs **no** FP operations at all — only AND/OR over
raw PRNG bits. On GPU this relieves the CUDA-core bottleneck; on the TPU
model it keeps the generator on cheap VPU bit ops with no transcendentals
(DESIGN.md §Hardware-Adaptation).

Two kernels:

* :func:`bitwise_noise` — consumes pre-generated random words
  (``jax.random.bits``; the "GPU PRNG" analog) with 4 words per 32 lanes
  (rotation-reuse construction, bit-exact vs ``ref.noise_planes_fast``).
* :func:`box_muller_noise` — the conventional generator the paper benchmarks
  against in Fig. 6: uniform → Box–Muller → divide → round, all in FP.

Both are lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls) and are shape-polymorphic over the leading dimension via the
grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Rows of 32-lane groups processed by one kernel program.
_TILE_G = 512


def _tile_rows(g: int) -> int:
    """Largest divisor of g that is <= _TILE_G (grid must tile exactly)."""
    t = min(_TILE_G, g)
    while g % t != 0:
        t -= 1
    return t


def _bitwise_kernel(r_ref, o_ref):
    """One tile: (g, 4) uint32 words -> (g, 32) f32 noise values."""
    r = r_ref[...]
    a, b, c = r[:, 1], r[:, 2], r[:, 3]
    rot = ref.rotl
    chain = (
        b & rot(b, 7) & rot(b, 13) & rot(b, 22)
        & c & rot(c, 5) & rot(c, 17) & rot(c, 26)
    )
    mag2 = (a | rot(a, 11)) & chain
    mag1 = (rot(a, 3) | rot(b, 29)) & (rot(c, 9) | rot(a, 19)) & rot(b, 16) & ~mag2
    sign = r[:, 0]
    lanes = jnp.arange(32, dtype=jnp.uint32)

    def bit(word):
        return ((word[:, None] >> lanes) & 1).astype(jnp.float32)

    s, m1, m2 = bit(sign), bit(mag1), bit(mag2)
    mag = m1 + 2.0 * m2
    o_ref[...] = jnp.where(s == 1.0, -mag, mag)


def bitwise_noise(bits: jnp.ndarray) -> jnp.ndarray:
    """Eq. 10 noise from random words: (G, 4) uint32 -> (G, 32) f32.

    G must be a multiple of ``_TILE_G`` or smaller than it (single tile).
    Values are in {-2, -1, 0, +1, +2} with the Eq. 10 probabilities.
    """
    g = bits.shape[0]
    tile = _tile_rows(g)
    return pl.pallas_call(
        _bitwise_kernel,
        grid=(g // tile,),
        in_specs=[pl.BlockSpec((tile, 4), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, 32), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, 32), jnp.float32),
        interpret=True,
    )(bits)


def _box_muller_kernel(r_ref, o_ref):
    """Conventional path: 2x uint32 -> U(0,1) -> Box-Muller -> round(N/2)."""
    r = r_ref[...]
    u1 = (r[:, 0:16].astype(jnp.float32) + 1.0) / 4294967296.0  # (0, 1]
    u2 = r[:, 16:32].astype(jnp.float32) / 4294967296.0
    rad = jnp.sqrt(-2.0 * jnp.log(u1))
    theta = 2.0 * jnp.pi * u2
    n1 = rad * jnp.cos(theta)
    n2 = rad * jnp.sin(theta)
    n = jnp.concatenate([n1, n2], axis=-1)
    o_ref[...] = jnp.round(n / 2.0)


def box_muller_noise(bits: jnp.ndarray) -> jnp.ndarray:
    """Exact rounded normal from random words: (G, 32) uint32 -> (G, 32) f32.

    This is the Fig. 6 "bm" comparison arm: one random word per output
    element, plus log/sqrt/cos per pair.
    """
    g = bits.shape[0]
    tile = _tile_rows(g)
    return pl.pallas_call(
        _box_muller_kernel,
        grid=(g // tile,),
        in_specs=[pl.BlockSpec((tile, 32), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, 32), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, 32), jnp.float32),
        interpret=True,
    )(bits)


@functools.partial(jax.jit, static_argnums=(1, 2))
def noise_matrix(key, m: int, n: int) -> jnp.ndarray:
    """Generate an (m, n) Eq. 10 noise matrix from a PRNG key.

    Random words come from ``jax.random.bits`` (threefry — the counter-based
    "GPU PRNG" of the paper's §3.6 seed hierarchy); the Pallas kernel turns
    them into noise values with pure bit ops. 4 words per 32 elements =
    0.125 words/element, vs 1 word/element for Box–Muller.
    """
    total = m * n
    assert total % 32 == 0, (m, n)
    g = total // 32
    bits = jax.random.bits(key, (g, 4), jnp.uint32)
    return bitwise_noise(bits).reshape(m, n)


def uniform_matrix(key, m: int, n: int) -> jnp.ndarray:
    """DiffQ noise: U(-0.5, 0.5), bf16-rounded (the DiffQ arm runs the same
    BF16 operator), returned as f32."""
    u = jax.random.uniform(key, (m, n), jnp.float32) - 0.5
    return u.astype(jnp.bfloat16).astype(jnp.float32)
