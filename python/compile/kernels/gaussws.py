"""L1 Pallas kernel: the Eq. 3 sampling op.

One kernel program owns a (TM, TN) VMEM tile of the weight matrix (TM/TN are
multiples of the 32-element MX block), computes the square-blockwise
``max|w|`` *inside* the tile via reshape-reductions, applies the scaled
noise, and writes the bf16 sample:

    what = bf16( w + R * (max_bl|w| * 2^(1 - b_t)) )

BlockSpec expresses the HBM->VMEM schedule the paper's Triton kernel did
with threadblocks (DESIGN.md §Hardware-Adaptation): the tile is the unit of
memory traffic, the 32x32 sub-blocks are the quantization groups.

The op is wrapped in ``jax.custom_vjp`` implementing Eq. 4 exactly:

    dL/dw   = g                        (identity pass-through)
    dL/db_t = -ln2 * amax * 2^(1-b_t) * block_sum(g * R)

with the ``d max|w| / dw ~= 0`` approximation from the paper.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK = ref.BLOCK  # 32, MX block size


def _tile(dim: int, pref: int = 256) -> int:
    """Largest tile <= pref that divides dim and is a multiple of BLOCK."""
    t = min(dim, pref)
    while t > BLOCK and (dim % t != 0 or t % BLOCK != 0):
        t -= BLOCK
    assert dim % t == 0 and t % BLOCK == 0, (dim, t)
    return t


def _sample_kernel(w_ref, bt_ref, r_ref, o_ref):
    """One (TM, TN) tile of Eq. 3."""
    w = w_ref[...]
    bt = bt_ref[...]
    r = r_ref[...]
    tm, tn = w.shape
    gm, gn = tm // BLOCK, tn // BLOCK
    blocks = jnp.abs(w).reshape(gm, BLOCK, gn, BLOCK)
    amax = blocks.max(axis=(1, 3))  # (gm, gn)
    scale = amax * jnp.exp2(1.0 - bt)  # (gm, gn)
    scale_full = jnp.broadcast_to(
        scale[:, None, :, None], (gm, BLOCK, gn, BLOCK)
    ).reshape(tm, tn)
    o_ref[...] = (w + r * scale_full).astype(jnp.bfloat16)


def sample_fwd_kernel(w: jnp.ndarray, bt: jnp.ndarray, noise: jnp.ndarray) -> jnp.ndarray:
    """Pallas-backed Eq. 3 forward. w, noise: (m, n) f32; bt: (m/32, n/32)."""
    m, n = w.shape
    tm, tn = _tile(m), _tile(n)
    return pl.pallas_call(
        _sample_kernel,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
            pl.BlockSpec((tm // BLOCK, tn // BLOCK), lambda i, j: (i, j)),
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.bfloat16),
        interpret=True,
    )(w, bt, noise)


# ---------------------------------------------------------------------------
# custom-vjp wrapper (Eq. 4)


@jax.custom_vjp
def pq_sample(w: jnp.ndarray, bt: jnp.ndarray, noise: jnp.ndarray) -> jnp.ndarray:
    """Differentiable Eq. 3 sample; gradients per Eq. 4.

    ``noise`` is treated as a constant sample (zero cotangent); it must be
    the same array in forward and backward, which the caller guarantees by
    construction (it is a saved residual).
    """
    return sample_fwd_kernel(w, bt, noise)


def _pq_fwd(w, bt, noise):
    what = sample_fwd_kernel(w, bt, noise)
    amax = ref.block_absmax(w, BLOCK)
    return what, (amax, bt, noise)


def _pq_bwd(res, g):
    amax, bt, noise = res
    g32 = g.astype(jnp.float32)
    scale = amax * jnp.exp2(1.0 - bt)
    dbt = -math.log(2.0) * scale * ref.block_sum(g32 * noise, BLOCK)
    return g32, dbt, None


pq_sample.defvjp(_pq_fwd, _pq_bwd)


# ---------------------------------------------------------------------------
# convenience: full layer op (noise generation + sampling)


@functools.partial(jax.jit, static_argnums=(3,))
def gaussws_layer(w, bt, key, use_bitwise: bool = True):
    """Generate Eq. 10 noise for ``w`` and sample ŵ. Returns (what_bf16, R)."""
    from . import noise as noise_mod

    m, n = w.shape
    if use_bitwise:
        r = noise_mod.noise_matrix(key, m, n)
    else:
        bits = jax.random.bits(key, (m * n // 32, 32), jnp.uint32)
        r = noise_mod.box_muller_noise(bits).reshape(m, n)
    return pq_sample(w, bt, r), r
