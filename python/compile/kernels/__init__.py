"""L1 Pallas kernels and their pure-jnp reference oracles."""

from . import diffq, gaussws, noise, ref  # noqa: F401
