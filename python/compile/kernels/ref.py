"""Pure-jnp reference oracles for the L1 Pallas kernels.

Everything here is straight-line jnp with no pallas: the pytest suite
asserts the kernels match these to bit accuracy (noise construction is
integer-exact; sampling matches after identical bf16 rounding).
"""

from __future__ import annotations

import math

import jax.numpy as jnp

# Square block size b_l, fixed to the MX convention (paper Section 3.2).
BLOCK = 32

# ---------------------------------------------------------------------------
# blockwise helpers


def block_absmax(w: jnp.ndarray, block: int = BLOCK) -> jnp.ndarray:
    """Square-blockwise max(|w|): (m, n) -> (m/b, n/b).

    m and n must be multiples of `block` (the model pads its weights).
    """
    m, n = w.shape
    assert m % block == 0 and n % block == 0, (m, n, block)
    blocks = jnp.abs(w).reshape(m // block, block, n // block, block)
    return blocks.max(axis=(1, 3))


def broadcast_blocks(s: jnp.ndarray, block: int = BLOCK) -> jnp.ndarray:
    """Inverse of block reduction: (gm, gn) -> (gm*b, gn*b) by replication."""
    gm, gn = s.shape
    return jnp.broadcast_to(s[:, None, :, None], (gm, block, gn, block)).reshape(
        gm * block, gn * block
    )


def block_sum(x: jnp.ndarray, block: int = BLOCK) -> jnp.ndarray:
    """Square-blockwise sum: (m, n) -> (m/b, n/b)."""
    m, n = x.shape
    return x.reshape(m // block, block, n // block, block).sum(axis=(1, 3))


# ---------------------------------------------------------------------------
# Eq. 10 bitwise rounded-normal construction (mirrors rust prng::bitwise)


def noise_planes_exact(r: jnp.ndarray) -> jnp.ndarray:
    """Bit-parallel Eq. 10 R values from independent random words.

    `r` is uint32 with shape (..., 16): 16 fresh words per 32 output lanes.
    Returns int8 with shape (..., 32), values in {-2,-1,0,1,2}:

      mag2 = (r1|r2) & r3 & ... & r10              p = 3/4 * 2^-8
      mag1 = (r11|r12) & (r13|r14) & r15 & ~mag2   p = (3/4)^2 / 2
      sign = r0
    """
    assert r.dtype == jnp.uint32 and r.shape[-1] == 16
    sign = r[..., 0]
    mag2 = r[..., 1] | r[..., 2]
    for k in range(3, 11):
        mag2 = mag2 & r[..., k]
    mag1 = (r[..., 11] | r[..., 12]) & (r[..., 13] | r[..., 14]) & r[..., 15] & ~mag2
    lanes = jnp.arange(32, dtype=jnp.uint32)

    def bit(word):
        return ((word[..., None] >> lanes) & 1).astype(jnp.int8)

    s, m1, m2 = bit(sign), bit(mag1), bit(mag2)
    mag = m1 + 2 * m2
    return jnp.where(s == 1, -mag, mag)


def rotl(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Rotate-left on uint32 lanes."""
    k = k % 32
    if k == 0:
        return x
    return (x << jnp.uint32(k)) | (x >> jnp.uint32(32 - k))


def noise_planes_fast(r: jnp.ndarray) -> jnp.ndarray:
    """Fast 4-words/32-lanes variant (rotation reuse), mirroring
    rust `prng::bitwise::planes_fast` exactly. `r` shape (..., 4) uint32."""
    assert r.dtype == jnp.uint32 and r.shape[-1] == 4
    a, b, c = r[..., 1], r[..., 2], r[..., 3]
    chain = (
        b
        & rotl(b, 7)
        & rotl(b, 13)
        & rotl(b, 22)
        & c
        & rotl(c, 5)
        & rotl(c, 17)
        & rotl(c, 26)
    )
    mag2 = (a | rotl(a, 11)) & chain
    mag1 = (rotl(a, 3) | rotl(b, 29)) & (rotl(c, 9) | rotl(a, 19)) & rotl(b, 16) & ~mag2
    sign = r[..., 0]
    lanes = jnp.arange(32, dtype=jnp.uint32)

    def bit(word):
        return ((word[..., None] >> lanes) & 1).astype(jnp.int8)

    s, m1, m2 = bit(sign), bit(mag1), bit(mag2)
    mag = m1 + 2 * m2
    return jnp.where(s == 1, -mag, mag)


def eq10_probabilities() -> tuple:
    """(p_zero, p_one_each, p_two_each) of the Eq. 10 target distribution."""
    p2_each = 0.75 * 2.0**-9
    p_mag2 = 2 * p2_each
    p1_each = 0.75 * 0.75 * 0.25 * (1 - p_mag2)
    return 1 - 2 * p1_each - p_mag2, p1_each, p2_each


# ---------------------------------------------------------------------------
# Eq. 3 sampling


def gaussws_sample(
    w: jnp.ndarray, bt: jnp.ndarray, noise: jnp.ndarray, block: int = BLOCK
) -> jnp.ndarray:
    """Reference Eq. 3: bf16(w + R * broadcast(max|w| * 2^(1-bt))).

    w: (m, n) f32; bt: (m/b, n/b) f32; noise: (m, n) f32 in {-2..2}.
    Returns bf16.
    """
    amax = block_absmax(w, block)
    scale = broadcast_blocks(amax * jnp.exp2(1.0 - bt), block)
    return (w + noise * scale).astype(jnp.bfloat16)


def diffq_sample(
    w: jnp.ndarray, bt: jnp.ndarray, noise: jnp.ndarray, block: int = BLOCK
) -> jnp.ndarray:
    """DiffQ arm: same formula, uniform noise in (-0.5, 0.5)."""
    return gaussws_sample(w, bt, noise, block)


def gaussws_bt_grad(
    w: jnp.ndarray,
    bt: jnp.ndarray,
    noise: jnp.ndarray,
    g: jnp.ndarray,
    block: int = BLOCK,
) -> jnp.ndarray:
    """Reference Eq. 4: dL/dbt = -ln2 * amax * 2^(1-bt) * block_sum(g * R)."""
    amax = block_absmax(w, block)
    scale = amax * jnp.exp2(1.0 - bt)
    return -math.log(2.0) * scale * block_sum(g * noise, block)


# ---------------------------------------------------------------------------
# fp_{e,m} casting emulation (Section 3.3 analysis in jnp)


def fp_cast(x: jnp.ndarray, exp_bits: int, man_bits: int) -> jnp.ndarray:
    """Emulate RNE casting into an fp_{e,m} format (float64 math, IEEE-like
    with subnormals; saturating overflow). Mirrors rust FpFormat::cast."""
    x = x.astype(jnp.float64)
    bias = 2 ** (exp_bits - 1) - 1
    min_normal_exp = 1 - bias
    max_exp = (2**exp_bits - 1) - 1 - bias  # reserve top code for inf/nan
    max_finite = (2.0 - 2.0**-man_bits) * 2.0**max_exp

    a = jnp.abs(x)
    # exact binade exponent: frexp gives a = m * 2^e with m in [0.5, 1),
    # so floor(log2 a) = e - 1 (log2+floor is off-by-one near boundaries)
    _, e_raw = jnp.frexp(jnp.where(a > 0, a, 1.0))
    e = e_raw - 1
    eff_e = jnp.maximum(e, min_normal_exp)
    # ldexp is exact for power-of-two steps; exp2 is exp(x*ln2) on CPU and
    # drifts ~1e-15 at large exponents, which breaks bit-exact comparisons
    step = jnp.ldexp(jnp.ones_like(a), eff_e - man_bits)
    q = a / step
    r = jnp.round(q)  # jnp.round is round-half-to-even
    v = r * step
    v = jnp.minimum(v, max_finite)
    out = jnp.sign(x) * v
    return jnp.where(a == 0, x, out)


def bt_from_bi(bi: jnp.ndarray, b_init: float, b_target: float) -> jnp.ndarray:
    """Eq. 11 linear bitwidth map."""
    return b_target + bi * (b_init - b_target)
