"""DiffQ baseline arm: the same Eq. 3/4 machinery with uniform U(-0.5, 0.5)
noise — the paper's "DiffQ" extension (Section 4: "equivalent to GaussWS
except for BF16 U(-0.5,0.5) in place of round(N(0,1)/2)").

Reuses the Pallas sampling kernel from :mod:`.gaussws`; only the noise
source differs, which is exactly the paper's ablation axis.
"""

from __future__ import annotations

import jax

from . import noise as noise_mod
from .gaussws import pq_sample


def diffq_layer(w, bt, key):
    """Uniform-noise sample of ŵ. Returns (what_bf16, R)."""
    m, n = w.shape
    r = noise_mod.uniform_matrix(key, m, n)
    return pq_sample(w, bt, r), r


__all__ = ["diffq_layer", "pq_sample"]


def _smoke():  # pragma: no cover - manual check
    import jax.numpy as jnp

    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    bt = jnp.full((2, 2), 4.0)
    what, r = diffq_layer(w, bt, jax.random.PRNGKey(1))
    assert what.shape == w.shape and r.shape == w.shape


if __name__ == "__main__":  # pragma: no cover
    _smoke()
    print("diffq smoke ok")
