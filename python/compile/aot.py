"""AOT lowering: JAX/Pallas graphs -> HLO *text* artifacts + manifest.json.

HLO text (NOT serialized protos) is the interchange format: the `xla` crate
links xla_extension 0.5.1, which rejects jax>=0.5 protos (64-bit instruction
ids); the text parser reassigns ids and round-trips cleanly.

Each artifact records its flattened input/output signature in
`artifacts/manifest.json` so the rust runtime can marshal buffers without
any knowledge of jax pytrees. Flattening order is jax's: dict leaves in
sorted-key order, then positional args.

Usage:
    python -m compile.aot --out-dir ../artifacts [--only NAME] [--list]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# ---------------------------------------------------------------------------
# artifact specs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


_DTYPE_NAMES = {
    jnp.dtype("float32"): "f32",
    jnp.dtype("bfloat16"): "bf16",
    jnp.dtype("int32"): "s32",
    jnp.dtype("uint32"): "u32",
}


def _sig(tree):
    """Flatten a pytree of ShapeDtypeStructs into the manifest signature."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for (path, leaf) in paths:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        ) or "arg"
        out.append(
            {
                "name": name,
                "shape": list(leaf.shape),
                "dtype": _DTYPE_NAMES[jnp.dtype(leaf.dtype)],
            }
        )
    assert len(out) == len(leaves)
    return out


class Artifact:
    """One lowerable computation + its manifest entry."""

    def __init__(self, name, kind, fn, example_args, meta=None):
        self.name = name
        self.kind = kind
        self.fn = fn
        self.example_args = example_args
        self.meta = meta or {}

    def lower_text(self) -> str:
        # keep_unused: the bf16 baseline ignores `seed`, but the manifest
        # signature (and the rust marshaller) must stay uniform across arms
        lowered = jax.jit(self.fn, keep_unused=True).lower(*self.example_args)
        mlir_mod = lowered.compiler_ir("stablehlo")
        comp = xc._xla.mlir.mlir_module_to_xla_computation(
            str(mlir_mod), use_tuple_args=False, return_tuple=True
        )
        return comp.as_hlo_text()

    def manifest_entry(self, out_shapes):
        return {
            "file": f"{self.name}.hlo.txt",
            "kind": self.kind,
            "inputs": _sig(self.example_args),
            "outputs": out_shapes,
            "meta": self.meta,
        }


def _model_artifacts(name, cfg: M.ModelCfg, pqt: M.PqtCfg, batch, with_eval=True):
    """Train (+ optional eval) artifacts for one (model, pqt) config."""
    params = jax.eval_shape(lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))
    bi = M.init_bi(cfg, pqt)
    bi_spec = {k: _sds(v.shape, jnp.float32) for k, v in bi.items()}
    x = _sds((batch, cfg.seq_len), jnp.int32)
    y = _sds((batch, cfg.seq_len), jnp.int32)
    seed = _sds((), jnp.int32)
    meta = {
        "arch": cfg.arch,
        "n_layer": cfg.n_layer,
        "d_model": cfg.d_model,
        "n_head": cfg.n_head,
        "d_ff": cfg.d_ff,
        "vocab": cfg.vocab,
        "seq_len": cfg.seq_len,
        "batch": batch,
        "method": pqt.method,
        "parts": list(pqt.parts),
        "b_init": pqt.b_init,
        "b_target": pqt.b_target,
        "lambda": pqt.lambda_,
        "param_names": sorted(params.keys()),
        "param_shapes": {k: list(v.shape) for k, v in params.items()},
        "bi_names": sorted(bi_spec.keys()),
        "bi_shapes": {k: list(v.shape) for k, v in bi_spec.items()},
    }
    arts = [
        Artifact(
            f"{name}.train",
            "train",
            M.train_step_fn(cfg, pqt),
            (params, bi_spec, x, y, seed),
            meta,
        )
    ]
    if with_eval:
        arts.append(
            Artifact(
                f"{name}.eval",
                "eval",
                M.eval_step_fn(cfg, pqt),
                (params, bi_spec, x, y, seed),
                meta,
            )
        )
    return arts


def _op_artifacts():
    """Standalone kernel-op artifacts (quickstart + runtime round-trip tests
    + the L1 bench driver)."""
    from .kernels import noise as noise_mod
    from .kernels.gaussws import sample_fwd_kernel

    arts = []
    # Eq. 10 bitwise noise: (G, 4) u32 -> (G, 32) f32
    g = 2048
    arts.append(
        Artifact(
            "op.noise_bitwise",
            "op",
            lambda bits: (noise_mod.bitwise_noise(bits),),
            (_sds((g, 4), jnp.uint32),),
            {"groups": g},
        )
    )
    # Box-Muller comparison: (G, 32) u32 -> (G, 32) f32
    arts.append(
        Artifact(
            "op.noise_boxmuller",
            "op",
            lambda bits: (noise_mod.box_muller_noise(bits),),
            (_sds((g, 32), jnp.uint32),),
            {"groups": g},
        )
    )
    # Eq. 3 sampling op on a 256x256 weight
    m = n = 256
    arts.append(
        Artifact(
            "op.gaussws_sample",
            "op",
            lambda w, bt, r: (sample_fwd_kernel(w, bt, r),),
            (
                _sds((m, n), jnp.float32),
                _sds((m // 32, n // 32), jnp.float32),
                _sds((m, n), jnp.float32),
            ),
            {"m": m, "n": n},
        )
    )
    return arts


# ---------------------------------------------------------------------------
# the default artifact set (kept deliberately explicit — this list IS the
# build matrix for the experiments in EXPERIMENTS.md)


def default_artifacts():
    arts = _op_artifacts()

    tiny_gpt2 = M.ModelCfg(arch="gpt2", n_layer=2, d_model=64, n_head=2,
                           d_ff=128, vocab=256, seq_len=64)
    tiny_llama = M.ModelCfg(arch="llama2", n_layer=2, d_model=64, n_head=2,
                            d_ff=128, vocab=256, seq_len=64)

    # Fig 1b / 3a arms (GPT2): baseline, GaussWS per-part, DiffQ
    gpt2_arms = [
        ("bf16", M.PqtCfg(method="none")),
        ("gaussws_all", M.PqtCfg(method="gaussws", parts=("all",))),
        ("gaussws_qkv", M.PqtCfg(method="gaussws", parts=("qkv",))),
        ("gaussws_out", M.PqtCfg(method="gaussws", parts=("out",))),
        ("gaussws_od", M.PqtCfg(method="gaussws", parts=("od",))),
        ("gaussws_up", M.PqtCfg(method="gaussws", parts=("up",))),
        ("gaussws_down", M.PqtCfg(method="gaussws", parts=("down",))),
        ("diffq_all", M.PqtCfg(method="diffq", parts=("all",))),
    ]
    for tag, pqt in gpt2_arms:
        arts += _model_artifacts(
            f"tiny_gpt2.{tag}", tiny_gpt2, pqt, batch=8,
            with_eval=(tag in ("bf16", "gaussws_all")),
        )

    # Fig 4 arms (Llama2): baseline, GaussWS, DiffQ + Fig F.1 (b 8->6)
    llama_arms = [
        ("bf16", M.PqtCfg(method="none")),
        ("gaussws_all", M.PqtCfg(method="gaussws", parts=("all",))),
        ("diffq_all", M.PqtCfg(method="diffq", parts=("all",))),
        ("gaussws_b8t6", M.PqtCfg(method="gaussws", parts=("all",),
                                  b_init=8.0, b_target=6.0)),
    ]
    for tag, pqt in llama_arms:
        arts += _model_artifacts(
            f"tiny_llama2.{tag}", tiny_llama, pqt, batch=8, with_eval=False,
        )

    # E2E driver: a ~3.4M-param GPT2 (the 1-core-CPU stand-in for the
    # paper's 124M; see DESIGN.md substitutions)
    small_gpt2 = M.ModelCfg(arch="gpt2", n_layer=4, d_model=256, n_head=4,
                            d_ff=1024, vocab=512, seq_len=128)
    for tag, pqt in [
        ("bf16", M.PqtCfg(method="none")),
        ("gaussws_all", M.PqtCfg(method="gaussws", parts=("all",))),
        ("diffq_all", M.PqtCfg(method="diffq", parts=("all",))),
    ]:
        arts += _model_artifacts(
            f"small_gpt2.{tag}", small_gpt2, pqt, batch=4, with_eval=(tag != "diffq_all"),
        )

    # Small llama for Table-1-style overhead ladder (second rung)
    small_llama = M.ModelCfg(arch="llama2", n_layer=4, d_model=256, n_head=4,
                             d_ff=704, vocab=512, seq_len=128)
    for tag, pqt in [
        ("bf16", M.PqtCfg(method="none")),
        ("gaussws_all", M.PqtCfg(method="gaussws", parts=("all",))),
        ("diffq_all", M.PqtCfg(method="diffq", parts=("all",))),
    ]:
        arts += _model_artifacts(
            f"small_llama2.{tag}", small_llama, pqt, batch=4, with_eval=False,
        )

    return arts


# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ap.add_argument("--list", action="store_true")
    # legacy single-file interface (kept for Makefile compatibility)
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    arts = default_artifacts()
    if args.list:
        for a in arts:
            print(f"{a.kind:6} {a.name}")
        return
    if args.only:
        arts = [a for a in arts if args.only in a.name]
        if not arts:
            print(f"no artifact matches '{args.only}'", file=sys.stderr)
            sys.exit(1)

    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"artifacts": {}}
    if os.path.exists(manifest_path) and args.only:
        with open(manifest_path) as f:
            manifest = json.load(f)

    for a in arts:
        # output signature via eval_shape on the jitted fn
        out_tree = jax.eval_shape(a.fn, *a.example_args)
        out_sig = _sig(out_tree)
        text = a.lower_text()
        path = os.path.join(out_dir, f"{a.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][a.name] = a.manifest_entry(out_sig)
        print(f"wrote {path} ({len(text) / 1e6:.2f} MB, "
              f"{len(out_sig)} outputs)")

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {manifest_path} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
